//! Zero-cost units-of-measure newtypes for the repo's quantity types.
//!
//! Every timing/energy/power quantity in the simulator used to travel as
//! a bare `f64` with a `_ns`/`_ms`/`_mj`/`_mw` naming convention and
//! ad-hoc `* 1e6` conversions at module boundaries. These newtypes move
//! that convention into the type system: [`Nanos`], [`Millis`],
//! [`Millijoules`], [`Milliwatts`] and [`Bytes`] are `#[repr(transparent)]`
//! f64 wrappers — same ABI, same arithmetic, zero runtime cost (see the
//! `units/overhead_smoke` rows in `BENCH_hotpath.json`) — but adding a
//! nanosecond to a millisecond, or comparing them, is a compile error.
//!
//! **Conversion ownership:** this module is the *only* sanctioned place
//! where time-scale factors live. `Nanos::to_millis` / `Millis::to_nanos`
//! are the two time-conversion sites in the whole crate; everything else
//! must route through them (enforced by `scripts/lint_invariants.py`,
//! which bans `1e6`/`1e-6` literals and `_ns: f64`-style declarations
//! outside this file).
//!
//! Same-unit arithmetic works as on raw scalars; scaling by dimensionless
//! factors works in both directions; the ratio of two like quantities is
//! a dimensionless `f64`:
//!
//! ```
//! use opima::util::units::{ms, ns, Millis, Nanos};
//! let total: Nanos = ns(1500.0) + 2.0 * ns(250.0);
//! assert_eq!(total, ns(2000.0));
//! assert_eq!(total.to_millis(), ms(0.002));
//! assert_eq!(ms(3.0) / ms(1.5), 2.0);
//! ```
//!
//! Cross-unit arithmetic and comparison do not compile:
//!
//! ```compile_fail
//! use opima::util::units::{Millis, Nanos};
//! let _ = Nanos::new(1.0) + Millis::new(1.0); // no Add<Millis> for Nanos
//! ```
//!
//! ```compile_fail
//! use opima::util::units::{Millis, Nanos};
//! assert!(Nanos::new(1.0) < Millis::new(1.0)); // no cross-unit ordering
//! ```

use std::cmp::Ordering;
use std::fmt;
use std::iter::Sum;
use std::ops::{Add, AddAssign, Div, Mul, Sub, SubAssign};
use std::time::Duration;

macro_rules! unit {
    ($(#[$doc:meta])* $name:ident, $suffix:literal) => {
        $(#[$doc])*
        #[derive(Debug, Clone, Copy, PartialEq, PartialOrd, Default)]
        #[repr(transparent)]
        pub struct $name(f64);

        impl $name {
            /// The zero quantity.
            pub const ZERO: Self = Self(0.0);

            /// Wrap a raw scalar already measured in this unit.
            pub const fn new(v: f64) -> Self {
                Self(v)
            }

            /// The raw scalar, measured in this unit. The escape hatch
            /// for genuinely unit-crossing arithmetic (energy = power ×
            /// time chains priced with explicit factor trails) and for
            /// display formatting — not for smuggling conversions.
            pub const fn raw(self) -> f64 {
                self.0
            }

            /// Larger of two quantities (IEEE `max`: ignores one NaN).
            pub fn max(self, other: Self) -> Self {
                Self(self.0.max(other.0))
            }

            /// Smaller of two quantities (IEEE `min`: ignores one NaN).
            pub fn min(self, other: Self) -> Self {
                Self(self.0.min(other.0))
            }

            /// Magnitude.
            pub fn abs(self) -> Self {
                Self(self.0.abs())
            }

            /// IEEE-754 total order over the underlying scalar — for
            /// heaps, sorts and `min_by`, exactly like `f64::total_cmp`.
            pub fn total_cmp(&self, other: &Self) -> Ordering {
                self.0.total_cmp(&other.0)
            }

            /// True when the underlying scalar is finite.
            pub fn is_finite(self) -> bool {
                self.0.is_finite()
            }
        }

        impl Add for $name {
            type Output = Self;
            fn add(self, rhs: Self) -> Self {
                Self(self.0 + rhs.0)
            }
        }

        impl Sub for $name {
            type Output = Self;
            fn sub(self, rhs: Self) -> Self {
                Self(self.0 - rhs.0)
            }
        }

        impl AddAssign for $name {
            fn add_assign(&mut self, rhs: Self) {
                self.0 += rhs.0;
            }
        }

        impl SubAssign for $name {
            fn sub_assign(&mut self, rhs: Self) {
                self.0 -= rhs.0;
            }
        }

        /// Scale by a dimensionless factor.
        impl Mul<f64> for $name {
            type Output = Self;
            fn mul(self, rhs: f64) -> Self {
                Self(self.0 * rhs)
            }
        }

        /// Scale by a dimensionless factor (commuted form, so existing
        /// `count as f64 * per_item` pricing keeps its operand order).
        impl Mul<$name> for f64 {
            type Output = $name;
            fn mul(self, rhs: $name) -> $name {
                $name(self * rhs.0)
            }
        }

        /// Divide by a dimensionless factor.
        impl Div<f64> for $name {
            type Output = Self;
            fn div(self, rhs: f64) -> Self {
                Self(self.0 / rhs)
            }
        }

        /// The ratio of two like quantities is dimensionless.
        impl Div for $name {
            type Output = f64;
            fn div(self, rhs: Self) -> f64 {
                self.0 / rhs.0
            }
        }

        impl Sum for $name {
            fn sum<I: Iterator<Item = Self>>(iter: I) -> Self {
                Self(iter.map(|v| v.0).sum())
            }
        }

        impl<'a> Sum<&'a $name> for $name {
            fn sum<I: Iterator<Item = &'a $name>>(iter: I) -> Self {
                Self(iter.map(|v| v.0).sum())
            }
        }

        /// Renders as `<value> <unit>`, forwarding width/precision flags
        /// to the scalar (`{:.3}` → `1.500 ms`).
        impl fmt::Display for $name {
            fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
                fmt::Display::fmt(&self.0, f)?;
                f.write_str(concat!(" ", $suffix))
            }
        }
    };
}

unit!(
    /// A duration in nanoseconds — the simulator's native timescale
    /// (stage costs, event times, pool free-times, makespans).
    Nanos,
    "ns"
);
unit!(
    /// A duration in milliseconds — the serving-layer timescale
    /// (request latencies, admission windows, report tables).
    Millis,
    "ms"
);
unit!(
    /// Energy in millijoules (per-inference and per-batch roll-ups).
    Millijoules,
    "mJ"
);
unit!(
    /// Power in milliwatts (per-device envelope knobs, link budgets).
    Milliwatts,
    "mW"
);
unit!(
    /// A byte count carried as a scalar (bandwidth/footprint math).
    Bytes,
    "B"
);

impl Nanos {
    /// The one sanctioned ns → ms conversion in the crate.
    pub fn to_millis(self) -> Millis {
        Millis(self.0 / 1e6)
    }

    /// Human-scaled rendering for bench tables: picks ns, µs, ms or s.
    pub fn human(self) -> String {
        let ns = self.0;
        if ns < 1e3 {
            format!("{ns:.1} ns")
        } else if ns < 1e6 {
            format!("{:.2} µs", ns / 1e3)
        } else if ns < 1e9 {
            format!("{:.3} ms", ns / 1e6)
        } else {
            format!("{:.3} s", ns / 1e9)
        }
    }
}

impl Millis {
    /// The one sanctioned ms → ns conversion in the crate.
    pub fn to_nanos(self) -> Nanos {
        Nanos(self.0 * 1e6)
    }

    /// A wall-clock duration as milliseconds.
    pub fn from_duration(d: Duration) -> Millis {
        Millis(d.as_secs_f64() * 1e3)
    }

    /// A milliseconds quantity as a wall-clock `Duration` (negative or
    /// NaN quantities clamp to zero — `Duration` cannot carry them).
    pub fn to_duration(self) -> Duration {
        if self.0.is_finite() && self.0 > 0.0 {
            Duration::from_secs_f64(self.0 / 1e3)
        } else {
            Duration::ZERO
        }
    }
}

impl Millijoules {
    /// Picojoules (the device-level pricing unit) rolled up to mJ.
    pub fn from_picojoules(pj: f64) -> Millijoules {
        Millijoules(pj / 1e9)
    }
}

/// Shorthand constructor: `ns(5.0)` reads better than `Nanos::new(5.0)`
/// in tests and pricing code.
pub fn ns(v: f64) -> Nanos {
    Nanos::new(v)
}

/// Shorthand constructor for [`Millis`].
pub fn ms(v: f64) -> Millis {
    Millis::new(v)
}

/// Shorthand constructor for [`Millijoules`].
pub fn mj(v: f64) -> Millijoules {
    Millijoules::new(v)
}

/// Shorthand constructor for [`Milliwatts`].
pub fn mw(v: f64) -> Milliwatts {
    Milliwatts::new(v)
}

/// Shorthand constructor for [`Bytes`].
pub fn bytes(v: f64) -> Bytes {
    Bytes::new(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn millis_duration_roundtrip_clamps_at_zero() {
        assert_eq!(ms(2.5).to_duration(), Duration::from_micros(2500));
        assert_eq!(Millis::from_duration(ms(2.5).to_duration()), ms(2.5));
        assert_eq!(ms(-1.0).to_duration(), Duration::ZERO);
        assert_eq!(ms(f64::NAN).to_duration(), Duration::ZERO);
    }

    #[test]
    fn arithmetic_matches_raw_scalars() {
        let a = ns(1500.0);
        let b = ns(250.0);
        assert_eq!((a + b).raw(), 1500.0 + 250.0);
        assert_eq!((a - b).raw(), 1500.0 - 250.0);
        assert_eq!((a * 3.0).raw(), 1500.0 * 3.0);
        assert_eq!((3.0 * a).raw(), 3.0 * 1500.0);
        assert_eq!((a / 4.0).raw(), 1500.0 / 4.0);
        assert_eq!(a / b, 1500.0 / 250.0);
        let mut acc = Nanos::ZERO;
        acc += a;
        acc -= b;
        assert_eq!(acc, ns(1250.0));
    }

    #[test]
    fn sum_folds_in_iteration_order() {
        // Sum must be bit-identical to the raw-f64 fold it replaced.
        let xs = [0.1f64, 0.7, 1e9, -3.0, 0.1];
        let raw: f64 = xs.iter().sum();
        let typed: Nanos = xs.iter().map(|&v| ns(v)).sum();
        assert_eq!(typed.raw(), raw);
        let by_ref: Millis = xs.iter().map(|&v| ms(v)).collect::<Vec<_>>().iter().sum();
        assert_eq!(by_ref.raw(), raw);
    }

    #[test]
    fn ordering_and_total_cmp() {
        assert!(ns(1.0) < ns(2.0));
        assert!(ms(5.0) >= ms(5.0));
        assert_eq!(ns(1.0).max(ns(2.0)), ns(2.0));
        assert_eq!(ns(1.0).min(ns(2.0)), ns(1.0));
        assert_eq!(ns(-3.0).abs(), ns(3.0));
        let mut v = vec![ns(3.0), ns(1.0), ns(2.0)];
        v.sort_by(Nanos::total_cmp);
        assert_eq!(v, vec![ns(1.0), ns(2.0), ns(3.0)]);
        assert!(ns(1.0).is_finite() && !ns(f64::INFINITY).is_finite());
    }

    #[test]
    fn display_carries_the_unit_and_precision() {
        assert_eq!(format!("{:.3}", ms(1.5)), "1.500 ms");
        assert_eq!(format!("{}", ns(2.0)), "2 ns");
        assert_eq!(format!("{:.1}", mj(0.25)), "0.2 mJ");
        assert_eq!(format!("{:.0}", mw(10.0)), "10 mW");
        assert_eq!(format!("{}", bytes(64.0)), "64 B");
    }

    #[test]
    fn human_rendering_scales() {
        assert_eq!(ns(12.0).human(), "12.0 ns");
        assert_eq!(ns(1500.0).human(), "1.50 µs");
        assert_eq!(ns(2.5e6).human(), "2.500 ms");
        assert_eq!(ns(3.2e9).human(), "3.200 s");
    }

    #[test]
    fn conversions_match_the_legacy_factors() {
        // to_millis is exactly `/ 1e6` and to_nanos exactly `* 1e6` —
        // the same literals the pre-units code used, so every migrated
        // scalar is bit-identical.
        let x = 1234.567;
        assert_eq!(ns(x).to_millis().raw(), x / 1e6);
        assert_eq!(ms(x).to_nanos().raw(), x * 1e6);
        assert_eq!(Millijoules::from_picojoules(x).raw(), x / 1e9);
        assert_eq!(
            Millis::from_duration(Duration::from_micros(2500)).raw(),
            0.0025 * 1e3
        );
    }

    #[test]
    fn round_trip_is_exact_for_representative_magnitudes() {
        // The admission boundary (router ms ↔ contention-engine ns)
        // crosses units once per batch; these representative magnitudes
        // (dyadic ms values spanning µs-class to multi-second requests)
        // have exactly representable products with 1e6, so the round
        // trip must be *exact*, not merely close.
        for k in [1u64, 3, 7, 100, 999, 4096, 1_000_000] {
            for scale in [-10i32, -4, 0, 4, 10] {
                let x = k as f64 * (scale as f64).exp2();
                let m = ms(x);
                assert_eq!(m.to_nanos().to_millis(), m, "{x} ms drifted");
                // No drift across repeated boundary crossings either.
                let mut y = m;
                for _ in 0..64 {
                    y = y.to_nanos().to_millis();
                }
                assert_eq!(y, m, "{x} ms drifted over repeated crossings");
            }
        }
    }

    #[test]
    fn round_trip_error_is_bounded_for_arbitrary_magnitudes() {
        // Non-dyadic values may round, but only once: a single crossing
        // lands within an ulp, and the crossed value is a fixed point of
        // further crossings in practice — guarded here over a PRNG sweep.
        let mut state = 0x9E3779B97F4A7C15u64;
        for _ in 0..1000 {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            let x = (state >> 11) as f64 / (1u64 << 53) as f64 * 1e4 + 1e-3;
            let once = ms(x).to_nanos().to_millis();
            assert!((once.raw() - x).abs() <= x * 1e-15, "{x} moved too far");
            let twice = once.to_nanos().to_millis();
            assert_eq!(twice, once, "{x}: round trip is not idempotent");
        }
    }

    #[test]
    fn zero_and_default() {
        assert_eq!(Nanos::default(), Nanos::ZERO);
        assert_eq!(Millis::ZERO.raw(), 0.0);
    }
}
