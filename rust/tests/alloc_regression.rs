//! Allocation-regression harness for the zero-copy serving data plane
//! (ISSUE 5 acceptance): a counting `#[global_allocator]` wraps the
//! system allocator in **this test binary only**, and the single test
//! below asserts that — after a warmup wave builds the plans, grows the
//! pooled buffers and populates the histogram shards — serving another
//! wave of requests through the sim backend performs no per-request
//! heap allocation for images or logits.
//!
//! What legitimately still allocates in steady state is bounded and
//! per-*batch*, not per-request: the batcher's drained-requests vec, the
//! worker's responses vec, an occasional fresh logits buffer while a
//! previous batch's views are still alive in the response ring, and the
//! results channel's internals. The pre-zero-copy engine additionally
//! paid, per batch, a fresh input `Vec` (one whole image copy *per
//! request*), a manifest `ArtifactInfo` clone, a fresh logits `Vec`, and
//! a `row.to_vec()` per response — which is exactly what the bounds
//! below would catch coming back.
//!
//! The test is deliberately single-`#[test]`: the counters are global to
//! the process, and libtest would otherwise interleave a second test's
//! allocations into the measured window.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

use opima::cnn::Model;
use opima::coordinator::engine::{Engine, EngineConfig};
use opima::coordinator::request::{ImageBuf, InferenceRequest, Variant};
use opima::runtime::{ExecutorSpec, Manifest};

static ALLOCS: AtomicU64 = AtomicU64::new(0);
static BYTES: AtomicU64 = AtomicU64::new(0);

/// System allocator with global alloc/byte counters (dealloc is
/// uncounted — the assertions are about allocation pressure).
struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        BYTES.fetch_add(new_size as u64, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

fn snapshot() -> (u64, u64) {
    (ALLOCS.load(Ordering::SeqCst), BYTES.load(Ordering::SeqCst))
}

const N: u64 = 256;
const ELEMS: usize = 144;

/// A wave of N LeNet int4 requests. The eight distinct images are built
/// once and shared — cloning an `ImageBuf` into a request is a refcount
/// bump, never a pixel copy.
fn wave(images: &[ImageBuf]) -> Vec<InferenceRequest> {
    (0..N)
        .map(|id| InferenceRequest {
            id,
            model: Model::LeNet,
            image: images[id as usize % images.len()].clone(),
            variant: Variant::Int4,
            arrival: Instant::now(),
            deadline: None,
            reply: None,
        })
        .collect()
}

#[test]
fn steady_state_serving_does_not_allocate_per_request_payloads() {
    let engine = Engine::new(
        EngineConfig {
            workers: 1,
            queue_capacity: 1024,
            instances: 1,
            // Large deadline: all batches form on the size trigger, and
            // N is a multiple of 8, so the flow is deterministic.
            max_wait: Duration::from_secs(60),
            executor: ExecutorSpec::Sim { work_factor: 1 },
            // Small ring: responses are evicted (and their logits views
            // dropped) quickly, so the worker's logits pool can recycle.
            history: 8,
            ..EngineConfig::default()
        },
        Manifest::synthetic(8, 12),
    )
    .unwrap();
    let images: Vec<ImageBuf> = (0..8)
        .map(|b| {
            (0..ELEMS)
                .map(|i| ((b * ELEMS + i) % 7) as f32 * 0.1)
                .collect()
        })
        .collect();

    // Warmup: build the LeNet plan, grow the worker's input buffer,
    // seed the logits pool, touch every histogram shard and channel.
    for req in wave(&images) {
        engine.submit_blocking(req).unwrap();
    }
    engine.drain().unwrap();
    assert_eq!(engine.completed(), N);

    // Pre-build the measured wave OUTSIDE the window (constructing the
    // requests is the caller's traffic; serving them is what we meter).
    let measured = wave(&images);

    let (a0, b0) = snapshot();
    for req in measured {
        engine.submit_blocking(req).unwrap();
    }
    engine.drain().unwrap();
    let (a1, b1) = snapshot();
    assert_eq!(engine.completed(), 2 * N);

    let allocs = a1 - a0;
    let bytes = b1 - b0;
    eprintln!("steady-state wave of {N}: {allocs} allocations, {bytes} bytes");

    // Per-request payload traffic is zero, so what remains is bounded
    // per-batch bookkeeping — far below one allocation per request. The
    // old data plane could not pass this: `row.to_vec()` alone was one
    // allocation per response (N of them), before the per-batch input
    // Vec, logits Vec and ArtifactInfo clone.
    assert!(
        allocs < N,
        "steady-state wave allocated {allocs} times for {N} requests \
         (≥ 1/request ⇒ a per-request allocation crept back in)"
    );
    // And no per-request pixel/logits copies: one image is 576 B, so a
    // data plane that copied each request's payload to the heap even
    // once would exceed this budget on images alone.
    let image_bytes = (ELEMS * std::mem::size_of::<f32>()) as u64;
    assert!(
        bytes < N * image_bytes,
        "steady-state wave allocated {bytes} B for {N} requests \
         (≥ {image_bytes} B/request ⇒ payloads are being copied per request)"
    );

    // The responses themselves are views into shared batch buffers:
    // rows of one batch alias one allocation, not eight.
    let responses = engine.responses();
    assert_eq!(responses.len(), 8, "ring retains the last batch");
    let seq = responses[0].batch_seq;
    assert!(responses.iter().all(|r| r.batch_seq == seq));
    let mut ptrs: Vec<usize> = responses
        .iter()
        .map(|r| r.logits.as_slice().as_ptr() as usize)
        .collect();
    ptrs.sort_unstable();
    let span = ptrs[ptrs.len() - 1] - ptrs[0];
    assert!(
        span < 8 * 4 * std::mem::size_of::<f32>(),
        "rows of one batch must alias one shared logits buffer (span {span} B)"
    );
    let mut engine = engine;
    engine.shutdown().unwrap();
}
