//! Chaos soak (ISSUE 10): the serving stack under a deterministic,
//! seeded fault schedule, over a real TCP loopback socket.
//!
//! The core invariant — **every submitted request gets exactly one
//! terminal outcome** (RESPONSE, BUSY, ERROR, DEADLINE_EXCEEDED) — is
//! asserted from both ends of the wire:
//!
//! - client side: `sent == responses + busy + failed + expired`, with
//!   or without automatic BUSY retries;
//! - engine side: `accepted == completed == served + failed + expired`,
//!   and every BUSY the clients ever saw reconciles exactly against the
//!   engine's shed + rejected counters.
//!
//! The armed soak injects worker panics (respawn path), worker stalls,
//! transient executor errors and delayed two-part reply writes, runs
//! the per-connection token-bucket limiter, floods a deliberately tiny
//! ingress queue, and churns raw connections that die mid-SUBMIT — all
//! from one fixed `[fault]` seed, so a failure replays. The disarmed
//! test pins the other half of the bargain: a fault section that is
//! present but `armed = false` leaves wire responses and `SimMetering`
//! bit-identical to a no-fault engine.
//!
//! `OPIMA_CHAOS_SMOKE=1` (ci.sh) bounds the soak so it stays cheap.

use std::io::Write;
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use opima::cnn::Model;
use opima::config::FaultParams;
use opima::coordinator::engine::{Engine, EngineConfig};
use opima::coordinator::net::frame::encode_header;
use opima::coordinator::net::protocol::{FrameHeader, FrameKind, HEADER_LEN};
use opima::coordinator::net::{run_load, LoadGenConfig, NetClient, NetReply, NetServer};
use opima::coordinator::request::Variant;
use opima::runtime::{ExecutorSpec, Manifest};
use opima::util::fault::silence_injected_panics;
use opima::util::units::ms;
use opima::OpimaConfig;

/// Sim-backed engine with the given `[fault]` section. The tiny ingress
/// queue is part of the chaos: overload floods must surface as BUSY
/// backpressure, never as lost requests.
fn chaos_engine(fault: FaultParams, workers: usize, queue_capacity: usize) -> Arc<Engine> {
    let mut hw = OpimaConfig::paper();
    hw.fault = fault;
    Arc::new(
        Engine::new(
            EngineConfig {
                workers,
                queue_capacity,
                instances: 1,
                max_wait: Duration::from_millis(5),
                hw,
                executor: ExecutorSpec::Sim { work_factor: 1 },
                history: 8,
            },
            Manifest::synthetic(8, 12),
        )
        .unwrap(),
    )
}

fn pixels() -> Vec<f32> {
    (0..Model::LeNet.input_elems()).map(|i| (i % 7) as f32 * 0.1).collect()
}

/// `n` raw connections that each die abruptly mid-SUBMIT-payload — no
/// shutdown handshake, the socket just vanishes under the reader.
fn churn(addr: &str, n: u64) {
    for k in 0..n {
        let mut s = TcpStream::connect(addr).unwrap();
        let mut hdr = [0u8; HEADER_LEN];
        encode_header(
            &FrameHeader {
                kind: FrameKind::Submit,
                model: 0,
                variant: 2,
                id: 90_000 + k,
                payload_len: (Model::LeNet.input_elems() * 4) as u32,
                aux: 0,
            },
            &mut hdr,
        );
        s.write_all(&hdr).unwrap();
        s.write_all(&vec![0u8; Model::LeNet.input_elems() * 2]).unwrap();
        drop(s);
    }
}

/// The armed soak. The schedule is pinned: seed 100 puts the *first*
/// panic probe of both worker salts under 0.10 (verified against the
/// repo PRNG), so whichever worker picks up the first batch panics and
/// `respawns >= 1` is deterministic, not probabilistic.
#[test]
fn chaos_soak_every_request_gets_exactly_one_terminal_outcome() {
    silence_injected_panics();
    let smoke = std::env::var("OPIMA_CHAOS_SMOKE").is_ok();
    let (connections, per_conn) = if smoke { (3usize, 24usize) } else { (6, 96) };

    let fault = FaultParams {
        armed: true,
        seed: 100,
        worker_panic: 0.10,
        worker_stall: 0.05,
        stall_ms: ms(2.0),
        exec_transient: 0.03,
        writer_delay: 0.10,
        writer_delay_ms: ms(1.0),
        conn_rate_rps: 4000.0,
        conn_burst: 8,
    };
    let engine = chaos_engine(fault, 2, 8);
    let server = NetServer::bind(Arc::clone(&engine), "127.0.0.1:0").unwrap();
    let addr = server.local_addr().to_string();

    churn(&addr, 4);
    let report = run_load(&LoadGenConfig {
        addr: addr.clone(),
        connections,
        requests_per_conn: per_conn,
        rate_rps: 0.0,
        mix: vec![(Model::LeNet, 1)],
        variant: Variant::Int4,
        window: 16,
        seed: 7,
        retry_max: 3,
        retry_backoff: ms(0.5),
        retry_backoff_cap: ms(8.0),
        deadline_ms: 2_000,
    })
    .unwrap();
    churn(&addr, 4);

    // Exactly-once at the client: one terminal outcome per submission.
    assert_eq!(report.sent as usize, connections * per_conn, "full quota submitted");
    assert_eq!(
        report.sent,
        report.responses + report.busy + report.failed + report.expired,
        "client terminal outcomes must partition submissions exactly \
         (responses {} busy {} failed {} expired {} retries {})",
        report.responses,
        report.busy,
        report.failed,
        report.expired,
        report.retries
    );

    // Clean teardown under chaos: the accept loop and every connection
    // thread wind down; shutdown must not hang or error.
    server.shutdown().unwrap();

    // Engine-side exactly-once: nothing accepted is ever dropped, and
    // the three terminal buckets partition completions.
    let s = engine.stats();
    assert_eq!(engine.accepted(), engine.completed(), "accepted work all completed");
    assert_eq!(
        s.served + s.failed + s.expired,
        engine.completed(),
        "engine terminal outcomes must partition completions"
    );
    // The two ledgers describe the same run: what clients saw is what
    // the engine did. (Retried-then-served requests count once on each
    // side — the shed submission never reached `accepted`.)
    assert_eq!(s.served, report.responses);
    assert_eq!(s.failed, report.failed);
    assert_eq!(s.expired, report.expired);
    // Every BUSY frame on the wire came from exactly one front-end shed
    // or one ingress rejection; clients either retried it or booked a
    // terminal busy.
    assert_eq!(s.shed + s.rejected, report.busy + report.retries);

    assert!(s.respawns >= 1, "seeded schedule panics each worker's first batch");
    assert!(s.failed > 0, "injected panics/transients must surface as ERROR outcomes");

    if let Ok(mut e) = Arc::try_unwrap(engine) {
        e.shutdown().unwrap();
    }
}

/// One request over the wire against an engine carrying the given fault
/// section; returns (predicted, logits bits, metering bits).
fn serve_one(fault: FaultParams) -> (usize, Vec<u32>, [u64; 3]) {
    let engine = chaos_engine(fault, 1, 64);
    let server = NetServer::bind(Arc::clone(&engine), "127.0.0.1:0").unwrap();
    let mut client = NetClient::connect(&server.local_addr().to_string()).unwrap();
    let px = pixels();
    client.submit(42, Model::LeNet, Variant::Int4, &px).unwrap();
    let out = match client.recv().unwrap() {
        NetReply::Response(r) => (
            r.predicted,
            r.logits.iter().map(|v| v.to_bits()).collect(),
            [
                r.sim.hw_latency_ms.raw().to_bits(),
                r.sim.hw_contended_ms.raw().to_bits(),
                r.sim.hw_energy_mj.raw().to_bits(),
            ],
        ),
        other => panic!("expected a response, got {other:?}"),
    };
    client.drain().unwrap();
    assert!(matches!(client.recv().unwrap(), NetReply::Fin));
    server.shutdown().unwrap();
    if let Ok(mut e) = Arc::try_unwrap(engine) {
        e.shutdown().unwrap();
    }
    out
}

/// `armed = false` must be *absolute*: a fault section with every
/// probability at 1.0 — but disarmed — serves bit-identically to an
/// engine with no fault section at all. (The token-bucket limiter is
/// gated by its own `conn_rate_rps` knob, left 0 here; it is a serving
/// defense, not an injection.)
#[test]
fn disarmed_fault_plane_is_bit_identical_to_no_fault_plane() {
    let baseline = serve_one(FaultParams::default());
    let disarmed = serve_one(FaultParams {
        armed: false,
        seed: 9,
        worker_panic: 1.0,
        worker_stall: 1.0,
        stall_ms: ms(50.0),
        exec_transient: 1.0,
        writer_delay: 1.0,
        writer_delay_ms: ms(50.0),
        ..FaultParams::default()
    });
    assert_eq!(baseline.0, disarmed.0, "predicted class");
    assert_eq!(baseline.1, disarmed.1, "logits must be bit-identical");
    assert_eq!(baseline.2, disarmed.2, "SimMetering f64s must be bit-identical");
}

/// A request whose deadline lapses while parked in the batcher gets the
/// DEADLINE_EXCEEDED terminal frame — not a response, not silence — and
/// the engine books it as expired, exactly once.
#[test]
fn deadline_exceeded_is_a_terminal_wire_outcome() {
    // No faults needed: deadlines are a serving feature. One request
    // against a batch size of 8 and a 50 ms flush parks in the batcher;
    // its 1 ms budget lapses ~48 ms before any batch would form.
    let mut hw = OpimaConfig::paper();
    hw.fault = FaultParams::default();
    let engine = Arc::new(
        Engine::new(
            EngineConfig {
                workers: 1,
                queue_capacity: 64,
                instances: 1,
                max_wait: Duration::from_millis(50),
                hw,
                executor: ExecutorSpec::Sim { work_factor: 1 },
                history: 8,
            },
            Manifest::synthetic(8, 12),
        )
        .unwrap(),
    );
    let server = NetServer::bind(Arc::clone(&engine), "127.0.0.1:0").unwrap();
    let mut client = NetClient::connect(&server.local_addr().to_string()).unwrap();
    let px = pixels();
    client
        .submit_with_deadline(7, Model::LeNet, Variant::Int4, &px, 1)
        .unwrap();
    match client.recv().unwrap() {
        NetReply::DeadlineExceeded { id } => assert_eq!(id, 7),
        other => panic!("expected DEADLINE_EXCEEDED, got {other:?}"),
    }
    client.drain().unwrap();
    assert!(matches!(client.recv().unwrap(), NetReply::Fin));
    server.shutdown().unwrap();
    let s = engine.stats();
    assert_eq!((s.served, s.expired), (0, 1), "expired exactly once, never served");
    assert_eq!(engine.accepted(), engine.completed());
    if let Ok(mut e) = Arc::try_unwrap(engine) {
        e.shutdown().unwrap();
    }
}
