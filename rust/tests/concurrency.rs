//! Concurrent-serving tests over the pipelined engine.
//!
//! These run on the deterministic sim executor backend with a synthetic
//! manifest, so they exercise the full queue → batcher → worker-pool →
//! sink pipeline in any environment — no PJRT library, no artifacts.

use std::collections::HashSet;
use std::time::{Duration, Instant};

use opima::cnn::Model;
use opima::coordinator::engine::{Engine, EngineConfig};
use opima::coordinator::request::{InferenceRequest, Variant};
use opima::runtime::{ExecutorSpec, Manifest};
use opima::Error;

fn engine(workers: usize, queue: usize, max_wait: Duration) -> Engine {
    Engine::new(
        EngineConfig {
            workers,
            queue_capacity: queue,
            instances: 2,
            max_wait,
            executor: ExecutorSpec::Sim { work_factor: 1 },
            ..EngineConfig::default()
        },
        Manifest::synthetic(8, 12),
    )
    .unwrap()
}

fn req(id: u64) -> InferenceRequest {
    let variant = match id % 3 {
        0 => Variant::Fp32,
        1 => Variant::Int8,
        _ => Variant::Int4,
    };
    InferenceRequest {
        id,
        model: Model::LeNet,
        image: (0..144).map(|i| ((id as usize + i) % 11) as f32 * 0.1).collect(),
        variant,
        arrival: Instant::now(),
        deadline: None,
        reply: None,
    }
}

/// Multi-producer threads submitting mixed variants: every response
/// arrives exactly once and the stats totals are consistent.
#[test]
fn multi_producer_exactly_once() {
    let producers = 4u64;
    let per = 64u64;
    let n = producers * per;
    let mut e = engine(4, 256, Duration::from_millis(1));
    std::thread::scope(|s| {
        for p in 0..producers {
            let eref = &e;
            s.spawn(move || {
                for i in 0..per {
                    eref.submit_blocking(req(p * per + i)).unwrap();
                }
            });
        }
    });
    e.drain().unwrap();

    let rs = e.responses();
    assert_eq!(rs.len(), n as usize, "every request answered");
    let ids: HashSet<u64> = rs.iter().map(|r| r.id).collect();
    assert_eq!(ids.len(), n as usize, "no response delivered twice");
    assert!(ids.iter().all(|&id| id < n), "no unknown ids");
    for r in &rs {
        assert_eq!(r.logits.len(), 4);
        assert!(r.logits.iter().all(|v| v.is_finite()));
        assert!(r.predicted < 4);
        assert!(
            r.form_ms <= r.queue_ms + opima::util::units::ms(1e-9),
            "formed before executing"
        );
        assert!(r.instance < 2);
        assert!(r.worker < 4);
    }

    let stats = e.stats();
    assert_eq!(stats.served, n);
    assert_eq!(stats.failed, 0);
    assert_eq!(e.accepted(), n);
    assert_eq!(e.completed(), n);
    assert!(stats.batches > 0);
    // Batches can hold at most 8 requests, so at least ⌈n/8⌉ executed;
    // energy is accounted once per executed batch.
    assert!(stats.batches >= n / 8);
    assert!(stats.sim_energy_mj.raw() > 0.0 && stats.sim_energy_mj.is_finite());
    assert!(stats.sim_makespan_ms.raw() > 0.0);
    e.shutdown().unwrap();
}

/// Regression test for the seed's idle-flush bug: a deadline-triggered
/// flush must complete with **no** further `submit` calls.
#[test]
fn idle_deadline_flush_fires_without_further_submits() {
    let mut e = engine(1, 64, Duration::from_millis(5));
    for id in 0..3 {
        e.submit(req(id)).unwrap();
    }
    // No flush(), no drain(), no further submits: only the batcher's
    // timer tick can flush these three sub-batch-size requests.
    let t0 = Instant::now();
    while e.completed() < 3 && t0.elapsed() < Duration::from_secs(5) {
        std::thread::sleep(Duration::from_millis(1));
    }
    assert_eq!(e.completed(), 3, "idle deadline flush never fired");
    assert_eq!(e.responses().len(), 3);
    e.shutdown().unwrap();
}

/// When the worker pool is saturated, the bounded pipeline fills up and
/// `submit` surfaces `Error::Backpressure` — and everything that *was*
/// accepted still completes.
#[test]
fn backpressure_when_pipeline_saturated() {
    // One slow worker (the sim work factor makes a batch take
    // milliseconds) and a 4-slot ingress queue: the batch channel fills,
    // the batcher blocks handing off its next batch, ingress fills, and
    // further submits must be rejected long before the 64-request burst
    // is absorbed.
    let mut e = Engine::new(
        EngineConfig {
            workers: 1,
            queue_capacity: 4,
            instances: 1,
            max_wait: Duration::from_secs(30),
            executor: ExecutorSpec::Sim { work_factor: 1000 },
            ..EngineConfig::default()
        },
        Manifest::synthetic(8, 12),
    )
    .unwrap();
    let mut ok = 0u64;
    let mut backpressured = 0u64;
    for i in 0..64 {
        match e.submit(req(3 * i + 2)) {
            // id % 3 == 2 → all Int4, so batches of 8 keep forming
            Ok(()) => ok += 1,
            Err(Error::Backpressure) => backpressured += 1,
            Err(other) => panic!("unexpected error: {other}"),
        }
    }
    assert!(backpressured > 0, "saturated pipeline must reject");
    assert!(ok >= 4, "at least the queued + in-flight requests accepted");
    assert_eq!(e.rejected(), backpressured);
    assert_eq!(e.accepted(), ok);

    e.drain().unwrap();
    assert_eq!(e.completed(), ok, "all accepted requests complete");
    assert_eq!(e.responses().len(), ok as usize);
    e.shutdown().unwrap();
}

/// Graceful shutdown drains in-flight work before joining the pipeline.
#[test]
fn shutdown_drains_inflight_work() {
    let mut e = engine(2, 128, Duration::from_millis(2));
    for id in 0..20 {
        e.submit_blocking(req(id)).unwrap();
    }
    e.shutdown().unwrap();
    assert_eq!(e.completed(), 20);
    assert_eq!(e.responses().len(), 20);
    // The engine refuses further work but stats stay readable.
    assert!(e.submit(req(99)).is_err());
    assert_eq!(e.stats().served, 20);
}

/// The worker pool actually spreads execution across workers.
#[test]
fn multiple_workers_share_the_load() {
    // A work factor large enough that one batch takes ~ms: while one
    // worker is busy the other must pick up the next formed batch.
    let mut e = Engine::new(
        EngineConfig {
            workers: 2,
            queue_capacity: 256,
            instances: 2,
            max_wait: Duration::from_millis(1),
            executor: ExecutorSpec::Sim { work_factor: 500 },
            ..EngineConfig::default()
        },
        Manifest::synthetic(8, 12),
    )
    .unwrap();
    std::thread::scope(|s| {
        for p in 0..4u64 {
            let eref = &e;
            s.spawn(move || {
                for i in 0..32 {
                    // Single variant → clean batch-of-8 formation.
                    let mut r = req(3 * (p * 32 + i) + 2);
                    r.id = p * 32 + i;
                    eref.submit_blocking(r).unwrap();
                }
            });
        }
    });
    e.drain().unwrap();
    let rs = e.responses();
    assert_eq!(rs.len(), 128);
    let workers: HashSet<usize> = rs.iter().map(|r| r.worker).collect();
    // With 16 batches and 2 workers pulling from one channel, both
    // workers should serve at least one batch.
    assert!(
        workers.len() == 2,
        "expected both workers used, saw {workers:?}"
    );
    e.shutdown().unwrap();
}
