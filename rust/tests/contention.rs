//! Property tests over the global contention timeline (ISSUE 6).
//!
//! The bounds the engine promises, over random CNN pairs:
//! - **isolated ≤ contended**: admitting a batch into shared pools can
//!   only delay it versus having the instance to itself,
//! - **contended ≤ serialized sum**: co-residency never costs more than
//!   running the streams back to back,
//! - **bit-exact single-batch equality**: one batch in flight on a
//!   drained instance reproduces `simulate_analysis_makespan` exactly,
//!   at any admission time — the paper reproduction is untouched,
//! - **no pool oversubscription**: at every event boundary across all
//!   co-resident batches, at most `aggregation_units` aggregations and
//!   `writeback_channels` writebacks are in flight,
//! - **retirement invariance**: dropping retired occupancy never
//!   changes the placements or makespans of still-live work,
//! - and end-to-end: every served response's contended window covers
//!   its isolated latency.
//!
//! proptest is unavailable offline, so these use the in-repo
//! deterministic PRNG with many random cases (seeds printed on failure).

use opima::analyzer::contention::{BatchStream, GlobalTimeline};
use opima::analyzer::latency::analyze_model;
use opima::analyzer::timeline::{simulate_analysis_makespan, Phase};
use opima::analyzer::ModelAnalysis;
use opima::cnn::graph::{Network, NetworkBuilder};
use opima::cnn::layer::TensorShape;
use opima::cnn::Model;
use opima::coordinator::Router;
use opima::util::prng::Rng;
use opima::util::units::{ms, ns, Millis, Nanos};
use opima::OpimaConfig;

/// Build a random small CNN: a few conv/pool stages and an FC head.
fn random_net(rng: &mut Rng, case: usize) -> Network {
    let side = 8 + 4 * rng.index(4); // 8..20
    let cin = 1 + rng.index(3);
    let mut b = NetworkBuilder::new(&format!("rand{case}"), TensorShape::new(side, side, cin));
    let stages = 1 + rng.index(3);
    for _ in 0..stages {
        let k = [1usize, 3, 3, 5][rng.index(4)];
        let cout = 4 << rng.index(3);
        b.conv(k, k, cout, 1, k / 2).unwrap();
        if rng.index(2) == 0 {
            b.pool(2, 2).unwrap();
        }
    }
    b.fc(1 + rng.index(16)).unwrap();
    b.build()
}

fn stream(a: &ModelAnalysis, batch: usize) -> BatchStream<'_> {
    BatchStream {
        costs: &a.layer_costs,
        batch,
        pipelined: a.occupancy.fits(),
    }
}

#[test]
fn prop_isolated_le_contended_le_serialized_sum() {
    let cfg = OpimaConfig::paper();
    let mut rng = Rng::new(6060);
    for case in 0..30 {
        // A random CNN pair, each with its own batch, co-admitted onto
        // one instance big enough that occupancy always co-resides —
        // all queueing in this test comes from pool contention.
        let a1 = analyze_model(&cfg, &random_net(&mut rng, case), [4u32, 8][rng.index(2)]).unwrap();
        let a2 =
            analyze_model(&cfg, &random_net(&mut rng, 100 + case), [4u32, 8][rng.index(2)]).unwrap();
        let b1 = 1 + rng.index(12);
        let b2 = 1 + rng.index(12);
        let iso1 = simulate_analysis_makespan(&cfg, &a1, b1).makespan_ns;
        let iso2 = simulate_analysis_makespan(&cfg, &a2, b2).makespan_ns;
        let mut gt = GlobalTimeline::new(1, usize::MAX / 2, &cfg.pipeline);
        let adm1 = gt.admit(0, a1.occupancy.subarrays_used, Nanos::ZERO, stream(&a1, b1), None);
        let adm2 = gt.admit(0, a2.occupancy.subarrays_used, Nanos::ZERO, stream(&a2, b2), None);
        // Isolated ≤ contended, per batch.
        assert!(
            adm1.makespan_ns >= iso1 - ns(1e-6),
            "case {case}: first admission beat its isolated makespan"
        );
        assert!(
            adm2.makespan_ns >= iso2 - ns(1e-6),
            "case {case}: contended {} < isolated {iso2}",
            adm2.makespan_ns
        );
        // Contended ≤ serialized sum, for the fleet.
        let serialized = iso1 + iso2;
        assert!(
            gt.makespan_ns() <= serialized * (1.0 + 1e-12) + ns(1e-6),
            "case {case}: contended fleet {} exceeds serialized {serialized}",
            gt.makespan_ns()
        );
    }
}

#[test]
fn prop_single_batch_admission_bit_exact_with_isolated_timeline() {
    let cfg = OpimaConfig::paper();
    let mut rng = Rng::new(7171);
    for case in 0..30 {
        let a = analyze_model(&cfg, &random_net(&mut rng, case), [4u32, 8][rng.index(2)]).unwrap();
        let batch = 1 + rng.index(16);
        let iso = simulate_analysis_makespan(&cfg, &a, batch).makespan_ns;
        let fp = a.occupancy.subarrays_used;
        let mut gt = GlobalTimeline::new(2, usize::MAX / 2, &cfg.pipeline);
        // Bit-exact at t = 0 on a fresh instance…
        let adm = gt.admit(0, fp, Nanos::ZERO, stream(&a, batch), None);
        assert_eq!(adm.makespan_ns, iso, "case {case}: fresh-instance admission drifted");
        // …at an arbitrary origin on the other (idle) instance…
        let origin = ns(rng.f64() * 1e9);
        let adm = gt.admit(1, fp, origin, stream(&a, batch), None);
        assert_eq!(adm.makespan_ns, iso, "case {case}: origin-shifted admission drifted");
        // …and again on instance 0 once its pools have fully drained —
        // the retirement frontier does not reset pools, draining does.
        let drained = gt.horizon_ns(0).max(gt.horizon_ns(1)) + ns(1.0);
        gt.advance(drained);
        let adm = gt.admit(0, fp, drained, stream(&a, batch), None);
        assert_eq!(adm.makespan_ns, iso, "case {case}: drained re-admission drifted");
    }
}

#[test]
fn prop_pools_never_oversubscribed_across_coresident_batches() {
    let cfg = OpimaConfig::paper();
    let mut rng = Rng::new(8282);
    for case in 0..12 {
        let a1 = analyze_model(&cfg, &random_net(&mut rng, case), 4).unwrap();
        let a2 = analyze_model(&cfg, &random_net(&mut rng, 200 + case), 8).unwrap();
        let mut gt = GlobalTimeline::new(1, usize::MAX / 2, &cfg.pipeline);
        let mut events = Vec::new();
        // Three streams co-admitted at staggered origins, all sharing
        // one instance's pools; events come back in absolute time.
        gt.admit(0, 1, Nanos::ZERO, stream(&a1, 1 + rng.index(6)), Some(&mut events));
        gt.admit(0, 1, Nanos::ZERO, stream(&a2, 1 + rng.index(6)), Some(&mut events));
        let mid = gt.makespan_ns() * rng.f64() * 0.5;
        gt.admit(0, 1, mid, stream(&a1, 1 + rng.index(6)), Some(&mut events));
        // At every event start, count in-flight events per shared pool
        // across ALL batches: never above the pool's capacity.
        for (phase, cap) in [
            (Phase::Aggregation, cfg.pipeline.aggregation_units),
            (Phase::Writeback, cfg.pipeline.writeback_channels),
        ] {
            let spans: Vec<(Nanos, Nanos)> = events
                .iter()
                .filter(|e| e.phase == phase && e.end_ns > e.start_ns)
                .map(|e| (e.start_ns, e.end_ns))
                .collect();
            for &(s, _) in &spans {
                let live = spans.iter().filter(|&&(a_, b_)| a_ <= s && s < b_).count();
                assert!(
                    live <= cap,
                    "case {case}: {live} concurrent {phase:?} events exceed pool of {cap}"
                );
            }
        }
    }
}

#[test]
fn prop_retirement_never_changes_live_placements() {
    let cfg = OpimaConfig::paper();
    let mut rng = Rng::new(9393);
    for case in 0..20 {
        let a = analyze_model(&cfg, &random_net(&mut rng, case), 4).unwrap();
        let batch = 1 + rng.index(8);
        let fp = 40 + rng.index(30);
        // Seed both engines with identical admissions.
        let mut pruned = GlobalTimeline::new(1, 100, &cfg.pipeline);
        let mut unpruned = pruned.clone();
        let mut t = Nanos::ZERO;
        for _ in 0..6 {
            let s = pruned.earliest_start(0, fp, t, ns(1e6));
            pruned.admit(0, fp, s, stream(&a, batch), None);
            unpruned.admit(0, fp, s, stream(&a, batch), None);
            t = s;
        }
        // Retire everything ending before a mid-timeline frontier in
        // one engine only (`advance` also moves the frontier; probe the
        // other engine from the same base so placement bases agree).
        let mid = pruned.makespan_ns() * rng.f64();
        pruned.advance(mid);
        assert!(
            pruned.live_reservations(0) <= unpruned.live_reservations(0),
            "case {case}: retirement grew the ledger"
        );
        // Still-live work is untouched: the same new admission gets the
        // same placement and the same contended makespan in both.
        let sp = pruned.earliest_start(0, fp, mid, ns(1e6));
        let su = unpruned.earliest_start(0, fp, mid, ns(1e6));
        assert_eq!(sp, su, "case {case}: retirement moved the next placement");
        let ap = pruned.admit(0, fp, sp, stream(&a, batch), None);
        let au = unpruned.admit(0, fp, su, stream(&a, batch), None);
        assert_eq!(
            ap.makespan_ns, au.makespan_ns,
            "case {case}: retirement changed a live batch's makespan"
        );
        assert_eq!(ap.end_ns, au.end_ns);
        assert_eq!(pruned.makespan_ns(), unpruned.makespan_ns());
    }
}

#[test]
fn prop_router_contended_bounds_over_random_pairs() {
    // The same bounds hold through the Router's placement policy
    // (earliest feasible start, contended commit).
    let cfg = OpimaConfig::paper();
    let mut rng = Rng::new(4747);
    for case in 0..15 {
        let a1 = analyze_model(&cfg, &random_net(&mut rng, case), 4).unwrap();
        let a2 = analyze_model(&cfg, &random_net(&mut rng, 300 + case), 8).unwrap();
        let b1 = 1 + rng.index(10);
        let b2 = 1 + rng.index(10);
        let iso1 = simulate_analysis_makespan(&cfg, &a1, b1).makespan_ms();
        let iso2 = simulate_analysis_makespan(&cfg, &a2, b2).makespan_ms();
        let mut r = Router::with_pools(1, cfg.geometry.total_subarrays(), &cfg.pipeline);
        let (_, s1, e1) = r.dispatch_batch(
            Model::LeNet,
            a1.occupancy.subarrays_used,
            Millis::ZERO,
            stream(&a1, b1),
            iso1,
        );
        let (_, s2, e2) = r.dispatch_batch(
            Model::Vgg16,
            a2.occupancy.subarrays_used,
            Millis::ZERO,
            stream(&a2, b2),
            iso2,
        );
        assert!(e1 - s1 >= iso1 - ms(1e-9), "case {case}: batch 1 beat isolation");
        assert!(e2 - s2 >= iso2 - ms(1e-9), "case {case}: batch 2 beat isolation");
        assert!(
            r.makespan_ms() <= s2 + iso1 + iso2 + ms(1e-6),
            "case {case}: fleet exceeded queueing + serialized sum"
        );
        assert_eq!(r.model_makespan_ms(Model::LeNet), e1);
        assert_eq!(r.model_makespan_ms(Model::Vgg16), e2);
    }
}

#[test]
fn served_responses_carry_contended_window_covering_isolated_latency() {
    // End to end through the engine: every response's contended window
    // is at least its isolated hardware latency (equal when alone).
    use opima::coordinator::engine::{Engine, EngineConfig};
    use opima::coordinator::request::{InferenceRequest, Variant};
    use opima::runtime::{ExecutorSpec, Manifest};
    use std::time::{Duration, Instant};

    let mut e = Engine::new(
        EngineConfig {
            workers: 2,
            queue_capacity: 256,
            instances: 2,
            max_wait: Duration::from_millis(1),
            executor: ExecutorSpec::Sim { work_factor: 1 },
            history: 4096,
            ..EngineConfig::default()
        },
        Manifest::synthetic(8, 12),
    )
    .unwrap();
    for id in 0..64u64 {
        let model = if id % 2 == 0 { Model::LeNet } else { Model::ResNet18 };
        let elems = model.input_elems();
        e.submit_blocking(InferenceRequest {
            id,
            model,
            image: (0..elems).map(|i| ((id as usize + i) % 13) as f32 * 0.1).collect(),
            variant: Variant::Int4,
            arrival: Instant::now(),
            deadline: None,
            reply: None,
        })
        .unwrap();
    }
    e.drain().unwrap();
    let rs = e.responses();
    assert!(!rs.is_empty());
    for r in &rs {
        assert!(r.sim.hw_latency_ms > Millis::ZERO);
        assert!(
            r.sim.hw_contended_ms >= r.sim.hw_latency_ms - ms(1e-9),
            "response {}: contended {} < isolated {}",
            r.id,
            r.sim.hw_contended_ms,
            r.sim.hw_latency_ms
        );
    }
    e.shutdown().unwrap();
}
