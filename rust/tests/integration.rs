//! Cross-module integration tests: the full simulator stack composed
//! end to end, plus the PJRT serving path when artifacts are present.

use std::path::Path;

use opima::analyzer::energy::energy_breakdown;
use opima::analyzer::metrics::workload_bits;
use opima::analyzer::{analyze_model, power_breakdown};
use opima::baselines::{evaluate_all, evaluate_opima};
use opima::cnn::{build_model, Model, ALL_MODELS};
use opima::mapper::map_network;
use opima::memory::MemoryController;
use opima::phys::{dse, link, mode};
use opima::pim::group;
use opima::runtime::Manifest;
use opima::OpimaConfig;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

#[test]
fn device_dse_feeds_architecture() {
    // The phys layer's chosen cell must support the architecture's bit
    // density — the cross-layer consistency the paper's §IV.A claims.
    let cfg = OpimaConfig::paper();
    let r = dse::run(&dse::DseSweep::default());
    let geom = opima::phys::gst::GstGeometry::new(r.optimum.width_um, r.optimum.thickness_nm);
    assert!(
        opima::phys::gst::max_bits_per_cell(&geom) >= cfg.geometry.bits_per_cell,
        "DSE optimum must support {} bits/cell",
        cfg.geometry.bits_per_cell
    );
}

#[test]
fn mdm_bound_matches_bank_count() {
    let cfg = OpimaConfig::paper();
    assert_eq!(mode::max_reliable_modes(), cfg.geometry.mdm_degree);
    assert!(cfg.geometry.banks <= cfg.geometry.mdm_degree);
}

#[test]
fn link_budgets_close_for_paper_geometry() {
    let cfg = OpimaConfig::paper();
    let pim = link::solve(
        &link::pim_read_path(&cfg.geometry),
        &cfg.losses,
        cfg.geometry.bits_per_cell,
        opima::util::units::mw(1.0),
    );
    assert!(pim.min_launch_mw.raw() < 5.0, "MDL-class power: {}", pim.min_launch_mw);
    let mem = link::solve(
        &link::memory_read_path(&cfg.geometry),
        &cfg.losses,
        cfg.geometry.bits_per_cell,
        opima::util::units::mw(1.0),
    );
    assert!(mem.soa_count >= 1 && mem.soa_count <= 4);
}

#[test]
fn memory_and_pim_share_the_row_budget() {
    // Fig. 7's "rows available" column must equal what the memory
    // controller actually has left after PIM reservations.
    let cfg = OpimaConfig::paper();
    let mut mem = MemoryController::new(&cfg).unwrap();
    let rows = mem.reserve_pim_rows().unwrap();
    let point = group::evaluate(&cfg, cfg.geometry.subarray_groups).unwrap();
    assert_eq!(mem.rows_available(), point.rows_available);
    mem.release_pim_rows(&rows).unwrap();
}

#[test]
fn every_model_flows_through_the_whole_stack() {
    let cfg = OpimaConfig::paper();
    for m in ALL_MODELS {
        let net = build_model(m).unwrap();
        for bits in [4u32, 8] {
            let mapped = map_network(&cfg, &net, bits).unwrap();
            let a = analyze_model(&cfg, &net, bits).unwrap();
            assert_eq!(a.layer_costs.len(), mapped.works.len());
            assert!(a.total_ms().raw() > 0.0);
            let e = energy_breakdown(&cfg, &a);
            assert!(e.dynamic_mj().raw() > 0.0);
            assert!((a.dynamic_mj - e.dynamic_mj()).abs().raw() < 1e-9);
        }
    }
}

#[test]
fn comparison_orderings_hold_paper_shape() {
    // Fig. 11/12: OPIMA must win on both metrics against all platforms,
    // per model, at 4-bit.
    let cfg = OpimaConfig::paper();
    for m in [Model::ResNet18, Model::InceptionV2, Model::MobileNet, Model::SqueezeNet] {
        let net = build_model(m).unwrap();
        let rs = evaluate_all(&cfg, &net, 4).unwrap();
        let bits = workload_bits(&net, 4);
        let o = &rs[0];
        assert_eq!(o.platform, "OPIMA");
        for r in rs.iter().skip(1) {
            assert!(
                r.epb_pj(bits) > o.epb_pj(bits),
                "{}: {} EPB must exceed OPIMA",
                m.name(),
                r.platform
            );
        }
        // FPS/W: OPIMA wins on geomean (asserted in the bench); per-model
        // the paper itself notes P100 can out-run OPIMA on 1×1-heavy
        // models, so no per-model assert here.
    }
}

#[test]
fn headline_throughput_vs_phpim() {
    // Abstract: "2.98× higher throughput ... than the best-known prior
    // work". Check the geomean latency advantage is in the right band.
    let cfg = OpimaConfig::paper();
    let mut ratios = Vec::new();
    for m in [Model::ResNet18, Model::InceptionV2, Model::MobileNet, Model::SqueezeNet] {
        let net = build_model(m).unwrap();
        let o = evaluate_opima(&cfg, &net, 4).unwrap();
        let p = opima::baselines::phpim::PhPim::new(&cfg).evaluate(&net, 4);
        ratios.push(p.latency_ms / o.latency_ms);
    }
    let gm = opima::analyzer::metrics::geomean_ratio(&ratios, &vec![1.0; ratios.len()]);
    assert!(
        (1.5..6.0).contains(&gm),
        "OPIMA vs PhPIM throughput advantage {gm:.2}× (paper 2.98×)"
    );
}

#[test]
fn power_envelope_stable_across_workloads() {
    // Fig. 8 is a configuration property, not a workload property.
    let cfg = OpimaConfig::paper();
    let p = power_breakdown(&cfg).total_w();
    assert!((47.5..64.3).contains(&p));
}

#[test]
fn config_overrides_propagate_to_results() {
    let base = OpimaConfig::paper();
    let mut fast = base.clone();
    fast.timing.write_ns = opima::util::units::ns(100.0); // 10× faster MLC writes
    let net = build_model(Model::ResNet18).unwrap();
    let a_base = analyze_model(&base, &net, 4).unwrap();
    let a_fast = analyze_model(&fast, &net, 4).unwrap();
    assert!(a_fast.writeback_ms < a_base.writeback_ms / 5.0);
    assert!((a_fast.processing_ms - a_base.processing_ms).abs().raw() < 1e-9);
}

#[test]
fn toml_config_file_roundtrip() {
    let cfg = OpimaConfig::paper();
    let dir = std::env::temp_dir().join("opima_integration");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("cfg.toml");
    std::fs::write(&path, cfg.to_toml()).unwrap();
    let back = OpimaConfig::from_toml_file(&path).unwrap();
    assert_eq!(cfg, back);
}

// ---- PJRT-backed tests (need `make artifacts`) --------------------------

#[test]
fn serving_path_end_to_end() {
    use opima::coordinator::{InferenceRequest, Server, ServerConfig, Variant};
    use std::time::Instant;
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    let manifest = Manifest::load(&dir).unwrap();
    let mut server = Server::new(ServerConfig::default(), manifest).unwrap();
    let elems = server.image_elems();
    // Deterministic class-0 image (horizontal stripes, cf. data.py).
    let mut image = vec![0f32; elems];
    let size = (elems as f64).sqrt() as usize;
    for r in 0..size {
        for c in 0..size {
            image[r * size + c] = (((r) / 2) % 2) as f32;
        }
    }
    for id in 0..16u64 {
        server
            .submit(InferenceRequest {
                id,
                model: opima::cnn::Model::LeNet,
                image: image.clone().into(),
                variant: Variant::Fp32,
                arrival: Instant::now(),
                deadline: None,
                reply: None,
            })
            .unwrap();
    }
    server.flush().unwrap();
    let responses = server.drain_responses();
    assert_eq!(responses.len(), 16);
    // A clean class-0 pattern must classify as class 0 at fp32 — only
    // meaningful on the real PJRT backend (the sim backend serves
    // deterministic pseudo-logits).
    if cfg!(feature = "pjrt") {
        let correct = responses.iter().filter(|r| r.predicted == 0).count();
        assert!(correct >= 15, "{correct}/16 classified as class 0");
    }
}

#[test]
fn quantized_artifacts_agree_with_fp32_mostly() {
    use opima::runtime::Executor;
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    let manifest = Manifest::load(&dir).unwrap();
    let batch = manifest.batch;
    let size = manifest.image_size;
    let mut ex = Executor::new(manifest).unwrap();
    // One clean image per class, then padding.
    let mut input = vec![0f32; batch * size * size];
    for (img, cls) in (0..batch).zip([0usize, 1, 2, 3].iter().cycle()) {
        for r in 0..size {
            for c in 0..size {
                let v = match cls {
                    0 => (r / 2) % 2,
                    1 => (c / 2) % 2,
                    2 => ((r + c) / 3) % 2,
                    _ => ((r / 3) + (c / 3)) % 2,
                };
                input[img * size * size + r * size + c] = v as f32;
            }
        }
    }
    let fp = ex.run_f32(&format!("cnn_fp32_b{batch}"), &[&input]).unwrap();
    let q8 = ex.run_f32(&format!("cnn_int8_b{batch}"), &[&input]).unwrap();
    let classes = fp.len() / batch;
    let argmax = |row: &[f32]| {
        row.iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .unwrap()
            .0
    };
    let mut agree = 0;
    for i in 0..batch {
        if argmax(&fp[i * classes..(i + 1) * classes])
            == argmax(&q8[i * classes..(i + 1) * classes])
        {
            agree += 1;
        }
    }
    // Agreement is only a meaningful check on the real PJRT backend —
    // the sim backend ignores artifact weights, so fp32 and int8 outputs
    // are identical and the bound would hold vacuously.
    if cfg!(feature = "pjrt") {
        assert!(agree * 10 >= batch * 7, "int8 agrees with fp32: {agree}/{batch}");
    }
}
