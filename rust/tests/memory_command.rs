//! Differential/property suite for the command-level writeback path
//! (ISSUE 8): the naive and scheduled controllers against each other,
//! against the flat analytical figure, and against physical lower
//! bounds — plus trace invariants and functional conservation through
//! the memory controller's cell stores.
//!
//! The contract under test (DESIGN.md §2.7):
//! - **single-image equivalence**: on any stream with one writeback in
//!   flight at a time and one channel, scheduled == naive exactly,
//! - **ordering**: over randomized job streams, naive ≥ scheduled ≥ the
//!   bank-bottleneck lower bound,
//! - **uncontended recovery**: at batch 1 on a drained instance the
//!   command models reproduce the flat `writeback_ns` pricing
//!   bit-exactly (for models whose inter-writeback gaps cover the GST
//!   reconfiguration — asserted as a guard, not assumed),
//! - **trace invariants**: per-bank Write windows never overlap, one
//!   Route per row switch, concurrent Writes never exceed the channel
//!   count,
//! - **divergence**: two co-resident batches make scheduled strictly
//!   cheaper than naive, while both still price at or above isolation.
//!
//! proptest is unavailable offline, so these use the in-repo
//! deterministic PRNG with many random cases (seeds printed on failure).

use opima::analyzer::contention::{BatchStream, GlobalTimeline};
use opima::analyzer::latency::analyze_model;
use opima::analyzer::timeline::simulate_analysis_makespan;
use opima::analyzer::ModelAnalysis;
use opima::cnn::{build_model, Model, ALL_MODELS};
use opima::config::{PipelineParams, WritebackModel};
use opima::memory::timing::GST_SWITCH_RECONFIG_NS;
use opima::memory::{
    MemoryController, NaiveWritebackController, ScheduledWritebackController, WbCommandKind,
    WbJob, WritebackController,
};
use opima::util::prng::Rng;
use opima::util::units::{ns, Nanos};
use opima::OpimaConfig;

/// Build a random decomposed job, with `flat_ns` computed in the same
/// float order `cost_layer` uses (`trains × train + settle`).
fn random_job(rng: &mut Rng, id: u64) -> WbJob {
    let trains = rng.index(6) as u64; // 0..=5 — zero-train jobs included
    let train = 100.0 * (1 + rng.index(5)) as f64;
    let settle = 10.0 * rng.index(3) as f64;
    WbJob {
        id,
        row: rng.index(64) as u64,
        trains,
        train_ns: ns(train),
        settle_ns: ns(settle),
        flat_ns: ns(trains as f64 * train + settle),
    }
}

#[test]
fn prop_scheduled_equals_naive_on_single_image_streams() {
    // One writeback in flight at a time (each job ready only after the
    // previous fully drained) and one channel: the scheduled controller
    // has nothing to overlap, so it must reproduce the naive reference
    // exactly — including route penalties and serial-shortcut pricing.
    let mut rng = Rng::new(1313);
    for case in 0..50 {
        let banks = 1 + rng.index(6);
        let mut naive = NaiveWritebackController::new(banks);
        let mut sched = ScheduledWritebackController::new(banks, 1);
        let mut ready = Nanos::ZERO;
        for id in 0..12u64 {
            let j = random_job(&mut rng, id);
            let n = naive.admit(Nanos::ZERO, ready, &j);
            let s = sched.admit(Nanos::ZERO, ready, &j);
            assert_eq!(
                s, n,
                "case {case} job {id}: single-image streams must price identically"
            );
            // Next job becomes ready only after this one drained (plus
            // an occasional idle gap).
            ready = n.1 + ns(50.0 * rng.index(3) as f64);
        }
    }
}

#[test]
fn prop_naive_ge_scheduled_ge_bank_bottleneck() {
    // Randomized contended streams (every job ready at t = 0): the
    // scheduled controller must never price a job above the naive
    // reference, and its makespan must respect the physical lower
    // bounds — per-bank serial train work and the channel capacity.
    let mut rng = Rng::new(4242);
    let eps = ns(1e-6);
    for case in 0..40 {
        let banks = 1 + rng.index(6);
        let channels = 1 + rng.index(4);
        let mut naive = NaiveWritebackController::new(banks);
        let mut sched = ScheduledWritebackController::new(banks, channels);
        let mut bank_work = vec![Nanos::ZERO; banks];
        let mut total_work = Nanos::ZERO;
        let mut naive_max = Nanos::ZERO;
        let mut sched_max = Nanos::ZERO;
        for id in 0..10u64 {
            let j = random_job(&mut rng, id);
            // Mirror the controllers' round-robin striping to account
            // the per-bank train work independently.
            for i in 0..j.trains {
                bank_work[((j.row + i) % banks as u64) as usize] += j.train_ns;
                total_work += j.train_ns;
            }
            let (_, n_end) = naive.admit(Nanos::ZERO, Nanos::ZERO, &j);
            let (_, s_end) = sched.admit(Nanos::ZERO, Nanos::ZERO, &j);
            assert!(
                s_end <= n_end + eps,
                "case {case} job {id}: scheduled {s_end} above naive {n_end}"
            );
            naive_max = naive_max.max(n_end);
            sched_max = sched_max.max(s_end);
        }
        let bank_bound = bank_work.iter().copied().fold(Nanos::ZERO, Nanos::max);
        let channel_bound = total_work / channels as f64;
        let bound = bank_bound.max(channel_bound);
        assert!(
            sched_max >= bound - eps,
            "case {case}: scheduled makespan {sched_max} beats the bottleneck {bound}"
        );
        assert!(
            naive_max >= sched_max - eps,
            "case {case}: naive makespan {naive_max} below scheduled {sched_max}"
        );
    }
}

/// The pairwise gap guard: every writeback's ready time covers the GST
/// route reconfiguration the bank may need, so a batch-1 stream runs as
/// a gapless serial chain and the command models recover the flat
/// figure bit-exactly (DESIGN.md §2.7). First job: the bank starts
/// unrouted, so its own compute must cover the reconfig; later jobs:
/// the previous job's staging drain plus this layer's compute must.
fn flat_recovery_guard(a: &ModelAnalysis) -> bool {
    let gst = GST_SWITCH_RECONFIG_NS;
    let c = &a.layer_costs;
    if c.is_empty() || c[0].mac_ns + c[0].aggregation_ns < gst {
        return false;
    }
    (1..c.len()).all(|k| c[k - 1].wb_settle_ns + c[k].mac_ns + c[k].aggregation_ns >= gst)
}

#[test]
fn uncontended_batch1_recovers_flat_bit_exactly() {
    let base = OpimaConfig::paper();
    let mut guarded = 0usize;
    for m in ALL_MODELS {
        let a = analyze_model(&base, &build_model(m).unwrap(), 4).unwrap();
        if !flat_recovery_guard(&a) {
            continue;
        }
        guarded += 1;
        let mut per = Vec::new();
        for wm in WritebackModel::ALL {
            let mut cfg = base.clone();
            cfg.memory.writeback_model = wm;
            per.push(simulate_analysis_makespan(&cfg, &a, 1).makespan_ns);
        }
        assert_eq!(per[0], per[1], "{}: naive drifted from flat at batch 1", m.name());
        assert_eq!(per[0], per[2], "{}: scheduled drifted from flat at batch 1", m.name());
    }
    // The guard must actually admit the paper's CNNs — ResNet18 in
    // particular (its gaps are µs-class against a 10 ns reconfig).
    let resnet = analyze_model(&base, &build_model(Model::ResNet18).unwrap(), 4).unwrap();
    assert!(flat_recovery_guard(&resnet), "resnet18 must satisfy the gap guard");
    assert!(guarded >= 2, "only {guarded} models exercised the bit-exact limit");
}

#[test]
fn prop_trace_busy_windows_and_route_accounting() {
    let mut rng = Rng::new(7777);
    for case in 0..25 {
        let banks = 2 + rng.index(4);
        let channels = 1 + rng.index(3);
        let mut sched = ScheduledWritebackController::with_trace(banks, channels);
        let mut naive = NaiveWritebackController::with_trace(banks);
        for id in 0..14u64 {
            let j = random_job(&mut rng, id);
            let ready = ns(150.0 * rng.index(8) as f64);
            sched.admit(Nanos::ZERO, ready, &j);
            naive.admit(Nanos::ZERO, ready, &j);
        }
        for (who, trace) in [("scheduled", sched.take_trace()), ("naive", naive.take_trace())] {
            // (a) Write windows on one bank never overlap: MLC program
            // trains hold the bank datapath exclusively.
            for b in 0..banks {
                let mut windows: Vec<(Nanos, Nanos)> = trace
                    .iter()
                    .filter_map(|c| match c.kind {
                        WbCommandKind::Write { bank, .. } if bank == b => {
                            Some((c.start_ns, c.end_ns))
                        }
                        _ => None,
                    })
                    .collect();
                windows.sort_by(|x, y| x.0.total_cmp(&y.0));
                for w in windows.windows(2) {
                    assert!(
                        w[1].0 >= w[0].1 - ns(1e-9),
                        "case {case} {who}: bank {b} windows overlap: {w:?}"
                    );
                }
            }
            // (b) One Route per row switch: replay each bank's row
            // sequence off the Write commands and count transitions.
            let routes = trace
                .iter()
                .filter(|c| matches!(c.kind, WbCommandKind::Route { .. }))
                .count();
            let mut routed = vec![None; banks];
            let mut switches = 0usize;
            let mut ordered: Vec<(Nanos, usize, u64)> = trace
                .iter()
                .filter_map(|c| match c.kind {
                    WbCommandKind::Write { bank, row } => Some((c.start_ns, bank, row)),
                    _ => None,
                })
                .collect();
            ordered.sort_by(|x, y| x.0.total_cmp(&y.0));
            for &(_, bank, row) in &ordered {
                if routed[bank] != Some(row) {
                    switches += 1;
                    routed[bank] = Some(row);
                }
            }
            assert_eq!(
                routes, switches,
                "case {case} {who}: route count must match row switches"
            );
        }
        // (c) Concurrent Writes never exceed the channel count (the
        // optical write-power quanta) — scheduled controller only; the
        // naive one is globally serialized anyway.
        let mut sched2 = ScheduledWritebackController::with_trace(banks, channels);
        for id in 0..14u64 {
            sched2.admit(Nanos::ZERO, Nanos::ZERO, &random_job(&mut rng, id));
        }
        let trace = sched2.take_trace();
        let spans: Vec<(Nanos, Nanos)> = trace
            .iter()
            .filter_map(|c| match c.kind {
                WbCommandKind::Write { .. } if c.end_ns > c.start_ns => {
                    Some((c.start_ns, c.end_ns))
                }
                _ => None,
            })
            .collect();
        for &(s, _) in &spans {
            let live = spans.iter().filter(|&&(a, b)| a <= s && s < b).count();
            assert!(
                live <= channels,
                "case {case}: {live} concurrent trains exceed {channels} channels"
            );
        }
    }
}

#[test]
fn cellstore_conserves_written_activations() {
    // Functional conservation behind the priced path: activations
    // written through the OPCM command layer read back intact, across
    // bank/row boundaries (the command-level writeback prices exactly
    // this machinery).
    let mut ctl = MemoryController::new(&OpimaConfig::paper()).unwrap();
    let data: Vec<u8> = (0..4096).map(|i| (i * 31 % 251) as u8).collect();
    ctl.write(640, &data).unwrap();
    let r = ctl.read(640, data.len() as u64).unwrap();
    assert_eq!(r.data.unwrap(), data, "writeback lost or corrupted cells");
    let s = ctl.stats();
    assert_eq!(s.bytes_written, s.bytes_read);
}

#[test]
fn coresident_batches_diverge_scheduled_below_naive() {
    // The headline differential: two co-resident ResNet18 batches on
    // one instance. The naive controller serializes their command
    // streams end to end; the scheduled one overlaps trains across
    // banks and channels — strictly cheaper, yet never below isolation.
    let cfg = OpimaConfig::paper();
    let a = analyze_model(&cfg, &build_model(Model::ResNet18).unwrap(), 4).unwrap();
    let stream = BatchStream {
        costs: &a.layer_costs,
        batch: 2,
        pipelined: a.occupancy.fits(),
    };
    let pipe = PipelineParams {
        writeback_channels: 2,
        ..cfg.pipeline.clone()
    };
    let banks = cfg.geometry.banks;
    let mut fleet = Vec::new();
    for model in [WritebackModel::Naive, WritebackModel::Scheduled] {
        let mut gt = GlobalTimeline::with_memory(1, usize::MAX / 2, &pipe, model, banks);
        let iso = {
            let mut fresh = GlobalTimeline::with_memory(1, usize::MAX / 2, &pipe, model, banks);
            fresh.admit(0, 1, Nanos::ZERO, stream, None).makespan_ns
        };
        gt.admit(0, 1, Nanos::ZERO, stream, None);
        let second = gt.admit(0, 1, Nanos::ZERO, stream, None);
        assert!(
            second.makespan_ns >= iso - ns(1e-6),
            "{model:?}: co-resident batch beat its isolated makespan"
        );
        fleet.push(gt.makespan_ns());
    }
    assert!(
        fleet[1] < fleet[0],
        "scheduled fleet {} must beat naive fleet {}",
        fleet[1],
        fleet[0]
    );
}
