//! Multi-model serving invariants over the pipelined engine and the
//! shared plan/cost registry (ISSUE 3 acceptance tests):
//!
//! (a) concurrent first-submissions of the same `(model, variant)` pair
//!     compile its plan exactly once,
//! (b) batches are never formed across models, and
//! (c) per-model served counts sum to the global count.
//!
//! Everything runs on the deterministic sim executor backend with a
//! synthetic manifest, so the full queue → batcher → worker-pool → sink
//! pipeline is exercised in any environment — no PJRT, no artifacts.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use opima::cnn::Model;
use opima::coordinator::engine::{Engine, EngineConfig};
use opima::coordinator::registry::{augment_manifest, PlanRegistry};
use opima::coordinator::request::{InferenceRequest, Variant};
use opima::runtime::{ExecutorSpec, Manifest};
use opima::util::units::{ms, Millijoules, Millis};
use opima::OpimaConfig;

fn engine(workers: usize) -> Engine {
    Engine::new(
        EngineConfig {
            workers,
            queue_capacity: 256,
            instances: 2,
            max_wait: Duration::from_millis(1),
            executor: ExecutorSpec::Sim { work_factor: 1 },
            history: 4096,
            ..EngineConfig::default()
        },
        Manifest::synthetic(8, 12),
    )
    .unwrap()
}

fn req(id: u64, model: Model, variant: Variant) -> InferenceRequest {
    let elems = model.input_elems();
    InferenceRequest {
        id,
        model,
        image: (0..elems).map(|i| ((id as usize + i) % 13) as f32 * 0.1).collect(),
        variant,
        arrival: Instant::now(),
        deadline: None,
        reply: None,
    }
}

/// (a), registry-level: N threads racing the first resolution of one
/// pair share exactly one build, and a different pair builds separately.
#[test]
fn racing_resolutions_compile_exactly_once() {
    let mut manifest = Manifest::synthetic(8, 12);
    augment_manifest(&mut manifest);
    let registry = Arc::new(PlanRegistry::new(OpimaConfig::paper(), manifest));
    std::thread::scope(|s| {
        for _ in 0..8 {
            let registry = Arc::clone(&registry);
            s.spawn(move || {
                let plan = registry.resolve(Model::ResNet18, Variant::Int4).unwrap();
                assert_eq!(plan.model, Model::ResNet18);
                assert_eq!(plan.classes(), 100);
            });
        }
    });
    assert_eq!(registry.builds(), 1, "8 racing threads, one build");
    registry.resolve(Model::ResNet18, Variant::Int8).unwrap();
    assert_eq!(registry.builds(), 2, "a distinct pair builds once more");
}

/// (a), engine-level: multi-producer mixed traffic over a racing worker
/// pool compiles each distinct `(model, variant)` pair exactly once.
#[test]
fn engine_compiles_each_pair_exactly_once_under_concurrency() {
    let producers = 4u64;
    let per = 32u64;
    let mut e = engine(4);
    std::thread::scope(|s| {
        for p in 0..producers {
            let eref = &e;
            s.spawn(move || {
                for i in 0..per {
                    let id = p * per + i;
                    // Three distinct pairs, interleaved from every
                    // producer so first-submissions race.
                    let (model, variant) = match id % 3 {
                        0 => (Model::LeNet, Variant::Int4),
                        1 => (Model::LeNet, Variant::Int8),
                        _ => (Model::ResNet18, Variant::Int4),
                    };
                    eref.submit_blocking(req(id, model, variant)).unwrap();
                }
            });
        }
    });
    e.drain().unwrap();
    assert_eq!(e.completed(), producers * per);
    assert_eq!(
        e.registry().builds(),
        3,
        "3 distinct (model, variant) pairs → exactly 3 plan builds"
    );
    e.shutdown().unwrap();
}

/// (b): responses sharing a batch carry one model — batches never form
/// across models (or variants), even with interleaved arrivals.
#[test]
fn batches_are_never_formed_across_models() {
    let mut e = engine(2);
    // Strictly interleaved arrivals: lenet, resnet, lenet, resnet, …
    // A batcher that ignored the model would happily mix these.
    let n = 64u64;
    for id in 0..n {
        let model = if id % 2 == 0 { Model::LeNet } else { Model::ResNet18 };
        e.submit_blocking(req(id, model, Variant::Int4)).unwrap();
    }
    e.drain().unwrap();
    let rs = e.responses();
    assert_eq!(rs.len(), n as usize);
    let mut by_batch: HashMap<u64, Vec<&opima::coordinator::InferenceResponse>> = HashMap::new();
    for r in &rs {
        by_batch.entry(r.batch_seq).or_default().push(r);
    }
    for (seq, group) in &by_batch {
        let model = group[0].model;
        assert!(
            group.iter().all(|r| r.model == model),
            "batch {seq} mixes models"
        );
        // And the payload matches the model's classifier head.
        let classes = model.classes();
        assert!(group.iter().all(|r| r.logits.len() == classes));
        assert!(group.len() <= e.batch_size());
    }
    // The requests parity-split ids by model; verify responses agree.
    for r in &rs {
        let expect = if r.id % 2 == 0 { Model::LeNet } else { Model::ResNet18 };
        assert_eq!(r.model, expect, "response {} served by wrong model", r.id);
    }
    e.shutdown().unwrap();
}

/// (c): the per-model breakdown partitions the global stats — served
/// counts, batches and sim energy all sum to the totals.
#[test]
fn per_model_served_counts_sum_to_global() {
    let mut e = engine(2);
    let n = 96u64;
    for id in 0..n {
        let model = match id % 4 {
            0 | 1 => Model::LeNet, // lenet:2, resnet:1, mobilenet:1
            2 => Model::ResNet18,
            _ => Model::MobileNet,
        };
        e.submit_blocking(req(id, model, Variant::Int4)).unwrap();
    }
    e.drain().unwrap();
    let s = e.stats();
    assert_eq!(s.served, n);
    assert_eq!(s.failed, 0);
    assert_eq!(s.per_model.len(), 3, "three active models");

    let served_sum: u64 = s.per_model.iter().map(|m| m.served).sum();
    let batch_sum: u64 = s.per_model.iter().map(|m| m.batches).sum();
    let failed_sum: u64 = s.per_model.iter().map(|m| m.failed).sum();
    let energy_sum: Millijoules = s.per_model.iter().map(|m| m.sim_energy_mj).sum();
    assert_eq!(served_sum, s.served, "per-model served partitions global");
    assert_eq!(batch_sum, s.batches, "per-model batches partition global");
    assert_eq!(failed_sum, s.failed);
    assert!(
        (energy_sum - s.sim_energy_mj).abs().raw() <= 1e-9 * s.sim_energy_mj.raw().max(1.0),
        "per-model energy {energy_sum} != global {}",
        s.sim_energy_mj
    );

    // Exact per-model counts follow the submitted mix.
    let served_of = |m: Model| {
        s.per_model
            .iter()
            .find(|x| x.model == m)
            .map(|x| x.served)
            .unwrap_or(0)
    };
    assert_eq!(served_of(Model::LeNet), n / 2);
    assert_eq!(served_of(Model::ResNet18), n / 4);
    assert_eq!(served_of(Model::MobileNet), n / 4);

    // Per-model latency shards cover exactly that model's responses,
    // and every model's tagged makespan is within the global one.
    for m in &s.per_model {
        assert_eq!(m.latency.total.count, m.served);
        assert!(m.latency.total.p50 <= m.latency.total.p99 + 1e-12);
        assert!(m.sim_makespan_ms > Millis::ZERO);
        assert!(m.sim_makespan_ms <= s.sim_makespan_ms + ms(1e-12));
        assert!(m.sim_energy_mj > Millijoules::ZERO);
    }
    // The heaviest model dominates the simulated energy bill.
    let energy_of = |m: Model| {
        s.per_model
            .iter()
            .find(|x| x.model == m)
            .map(|x| x.sim_energy_mj)
            .unwrap_or(Millijoules::ZERO)
    };
    assert!(energy_of(Model::ResNet18) > energy_of(Model::LeNet));
    e.shutdown().unwrap();
}
