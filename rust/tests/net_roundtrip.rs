//! Wire-front-end acceptance (ISSUE 9): the zero-copy TCP path measured
//! end to end over a real loopback socket.
//!
//! Four tests share this binary:
//!
//! 1. the **allocation proof** — a counting `#[global_allocator]` wraps
//!    the system allocator and a post-warmup wave of 256 requests
//!    (client *and* server in this process, so both sides of the wire
//!    are counted) must perform fewer than 1 allocation and fewer than
//!    1 image of heap bytes per request;
//! 2. **malformed-frame handling** — bad magic, oversized
//!    `payload_len`, truncated payloads and wrong-size submits must
//!    fail loudly without killing the accept loop (and per-request
//!    rejections must not even kill the connection);
//! 3. **abrupt client death** — a connection dying mid-SUBMIT-payload
//!    (socket dropped with no shutdown handshake, repeatedly) must
//!    leave the listener accepting and serving, with no partial
//!    request reaching the engine;
//! 4. **bit-identical transport** — a single request served over the
//!    socket must produce exactly the in-process `Engine::submit`
//!    response: same predicted class, bit-identical logits, and
//!    bit-identical `SimMetering` f64s.
//!
//! The allocator counters are process-global, so the tests serialize on
//! one mutex; the measured window opens only inside the alloc test's
//! critical section.

use std::alloc::{GlobalAlloc, Layout, System};
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use opima::cnn::Model;
use opima::coordinator::engine::{Engine, EngineConfig};
use opima::coordinator::net::frame::encode_header;
use opima::coordinator::net::protocol::{FrameHeader, FrameKind, HEADER_LEN, MAX_PAYLOAD};
use opima::coordinator::net::{NetClient, NetReply, NetServer};
use opima::coordinator::request::{InferenceRequest, Variant};
use opima::runtime::{ExecutorSpec, Manifest};

static ALLOCS: AtomicU64 = AtomicU64::new(0);
static BYTES: AtomicU64 = AtomicU64::new(0);

/// System allocator with global alloc/byte counters (dealloc is
/// uncounted — the assertions are about allocation pressure).
struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        BYTES.fetch_add(new_size as u64, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

/// Serializes the tests: the counters above are process-global.
static SERIAL: Mutex<()> = Mutex::new(());

fn snapshot() -> (u64, u64) {
    (ALLOCS.load(Ordering::SeqCst), BYTES.load(Ordering::SeqCst))
}

const ELEMS: usize = 144;

/// Sim-backed engine matching the alloc_regression harness (small ring,
/// views retire fast). The alloc test passes a large `max_wait` so every
/// batch forms on the size trigger deterministically; the single-request
/// tests pass a small one so a lone submit flushes on the deadline
/// instead of stalling.
fn engine_with(max_wait: Duration) -> Arc<Engine> {
    Arc::new(
        Engine::new(
            EngineConfig {
                workers: 1,
                queue_capacity: 1024,
                instances: 1,
                max_wait,
                executor: ExecutorSpec::Sim { work_factor: 1 },
                history: 8,
                ..EngineConfig::default()
            },
            Manifest::synthetic(8, 12),
        )
        .unwrap(),
    )
}

fn pixels() -> Vec<f32> {
    (0..ELEMS).map(|i| (i % 7) as f32 * 0.1).collect()
}

/// Submit `wave` requests and receive every reply on one connection —
/// windowed so in-flight images stay bounded and the server's
/// per-connection pool can recycle. Returns (responses, busy, failed).
fn drive_wave(client: &mut NetClient, px: &[f32], base_id: u64, wave: u64) -> (u64, u64, u64) {
    const WINDOW: u64 = 32;
    let (mut responses, mut busy, mut failed) = (0u64, 0u64, 0u64);
    let mut sent = 0u64;
    while sent < wave {
        let burst = WINDOW.min(wave - sent);
        for k in 0..burst {
            client
                .submit(base_id + sent + k, Model::LeNet, Variant::Int4, px)
                .unwrap();
        }
        sent += burst;
        for _ in 0..burst {
            match client.recv().unwrap() {
                NetReply::Response(_) => responses += 1,
                NetReply::Busy { .. } => busy += 1,
                NetReply::Failed { .. } => failed += 1,
                other => panic!("unexpected reply {other:?}"),
            }
        }
    }
    (responses, busy, failed)
}

#[test]
fn loopback_serving_does_less_than_one_alloc_per_request() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    const N: u64 = 256;
    let engine = engine_with(Duration::from_secs(60));
    let server = NetServer::bind(Arc::clone(&engine), "127.0.0.1:0").unwrap();
    let addr = server.local_addr().to_string();
    let mut client = NetClient::connect(&addr).unwrap();
    let px = pixels();

    // Warmup: plan build, pool growth, queue/scratch warming — on both
    // sides of the wire.
    let (r, b, f) = drive_wave(&mut client, &px, 0, N);
    assert_eq!((r, b, f), (N, 0, 0), "warmup wave fully served");

    let (a0, b0) = snapshot();
    let (r, bz, f) = drive_wave(&mut client, &px, N, N);
    let (a1, b1) = snapshot();
    assert_eq!((r, bz, f), (N, 0, 0), "measured wave fully served");

    let allocs = a1 - a0;
    let bytes = b1 - b0;
    eprintln!("loopback wave of {N}: {allocs} allocations, {bytes} bytes");
    // The whole socket→engine→socket round trip is in the window: frame
    // decode into pooled images, submit, batch, execute, reply-queue
    // push, vectored response write, client decode. <1 allocation per
    // request proves none of those stages allocates per request.
    assert!(
        allocs < N,
        "loopback wave allocated {allocs} times for {N} requests \
         (≥ 1/request ⇒ a per-request allocation crept into the wire path)"
    );
    let image_bytes = (ELEMS * std::mem::size_of::<f32>()) as u64;
    assert!(
        bytes < N * image_bytes,
        "loopback wave allocated {bytes} B for {N} requests \
         (≥ {image_bytes} B/request ⇒ request payloads are being copied to the heap)"
    );

    // Graceful drain: every response already flushed, then Fin.
    client.drain().unwrap();
    assert!(matches!(client.recv().unwrap(), NetReply::Fin));
    server.shutdown().unwrap();
    assert_eq!(engine.completed(), 2 * N);
    if let Ok(mut e) = Arc::try_unwrap(engine) {
        e.shutdown().unwrap();
    }
}

/// A raw frame header as bytes (for injecting malformed traffic).
fn raw_header(kind: FrameKind, model: u8, variant: u8, id: u64, payload_len: u32) -> [u8; HEADER_LEN] {
    let mut buf = [0u8; HEADER_LEN];
    encode_header(
        &FrameHeader {
            kind,
            model,
            variant,
            id,
            payload_len,
            aux: 0,
        },
        &mut buf,
    );
    buf
}

/// Read one raw reply header off a stream; `None` on EOF.
fn read_raw_kind(stream: &mut TcpStream) -> Option<u8> {
    let mut hdr = [0u8; HEADER_LEN];
    let mut got = 0;
    while got < HEADER_LEN {
        match stream.read(&mut hdr[got..]) {
            Ok(0) => return None,
            Ok(n) => got += n,
            Err(_) => return None,
        }
    }
    // Skip the payload so a following header read stays framed.
    let len = u32::from_le_bytes([hdr[16], hdr[17], hdr[18], hdr[19]]) as usize;
    let mut junk = vec![0u8; len];
    if stream.read_exact(&mut junk).is_err() {
        return None;
    }
    Some(hdr[4])
}

/// One full request/response roundtrip proving the server still serves.
fn roundtrip_serves(addr: &str, id: u64) {
    let mut client = NetClient::connect(addr).unwrap();
    let px = pixels();
    client.submit(id, Model::LeNet, Variant::Int4, &px).unwrap();
    match client.recv().unwrap() {
        NetReply::Response(r) => assert_eq!(r.id, id),
        other => panic!("expected a response, got {other:?}"),
    }
    client.drain().unwrap();
    assert!(matches!(client.recv().unwrap(), NetReply::Fin));
}

#[test]
fn malformed_frames_fail_loudly_without_killing_the_server() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let engine = engine_with(Duration::from_millis(5));
    let server = NetServer::bind(Arc::clone(&engine), "127.0.0.1:0").unwrap();
    let addr = server.local_addr().to_string();

    // Bad magic: the connection gets an Error frame (then Fin/close),
    // and the server survives.
    {
        let mut s = TcpStream::connect(&addr).unwrap();
        let mut hdr = raw_header(FrameKind::Submit, 0, 2, 1, 0);
        hdr[0] = b'X';
        s.write_all(&hdr).unwrap();
        let kinds = [read_raw_kind(&mut s), read_raw_kind(&mut s)];
        assert_eq!(
            kinds[0],
            Some(FrameKind::Error as u8),
            "bad magic answered with an Error frame"
        );
        assert!(
            matches!(kinds[1], Some(k) if k == FrameKind::Fin as u8) || kinds[1].is_none(),
            "stream ends after a desynced header"
        );
    }
    roundtrip_serves(&addr, 100);

    // Oversized payload_len: rejected at header parse — before any
    // buffer could be sized from the hostile length.
    {
        let mut s = TcpStream::connect(&addr).unwrap();
        let hdr = raw_header(FrameKind::Submit, 0, 2, 2, MAX_PAYLOAD + 1);
        s.write_all(&hdr).unwrap();
        assert_eq!(read_raw_kind(&mut s), Some(FrameKind::Error as u8));
    }
    roundtrip_serves(&addr, 101);

    // Truncated payload: a valid submit header whose pixels never
    // arrive. The reader EOFs mid-payload and ends the stream; no
    // request reaches the engine.
    {
        let before = engine.accepted();
        let mut s = TcpStream::connect(&addr).unwrap();
        let hdr = raw_header(FrameKind::Submit, 0, 2, 3, (ELEMS * 4) as u32);
        s.write_all(&hdr).unwrap();
        s.write_all(&[0u8; 10]).unwrap();
        s.shutdown(std::net::Shutdown::Write).unwrap();
        while read_raw_kind(&mut s).is_some() {}
        assert_eq!(engine.accepted(), before, "truncated submit never accepted");
    }
    roundtrip_serves(&addr, 102);

    // Wrong payload size for the model: a per-request rejection — the
    // SAME connection keeps serving afterwards.
    {
        let mut client = NetClient::connect(&addr).unwrap();
        let short = [0.5f32; 8];
        client.submit(4, Model::LeNet, Variant::Int4, &short).unwrap();
        match client.recv().unwrap() {
            NetReply::Failed { id, message } => {
                assert_eq!(id, 4);
                assert!(message.contains("payload"), "got: {message}");
            }
            other => panic!("expected Failed, got {other:?}"),
        }
        let px = pixels();
        client.submit(5, Model::LeNet, Variant::Int4, &px).unwrap();
        match client.recv().unwrap() {
            NetReply::Response(r) => assert_eq!(r.id, 5),
            other => panic!("expected a response, got {other:?}"),
        }
        client.drain().unwrap();
        assert!(matches!(client.recv().unwrap(), NetReply::Fin));
    }

    // Unknown model byte: also per-request.
    {
        let mut s = TcpStream::connect(&addr).unwrap();
        let hdr = raw_header(FrameKind::Submit, 250, 2, 6, 0);
        s.write_all(&hdr).unwrap();
        assert_eq!(read_raw_kind(&mut s), Some(FrameKind::Error as u8));
    }
    roundtrip_serves(&addr, 103);

    server.shutdown().unwrap();
    if let Ok(mut e) = Arc::try_unwrap(engine) {
        e.shutdown().unwrap();
    }
}

/// A client that dies mid-SUBMIT-payload — header plus a partial image,
/// then the socket is dropped with no shutdown handshake (the OS tears
/// the connection down under the reader, as a killed process would) —
/// must not take the listener with it: the accept loop keeps serving
/// fresh connections and the partial request never reaches the engine.
#[test]
fn client_death_mid_submit_payload_leaves_listener_serving() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let engine = engine_with(Duration::from_millis(5));
    let server = NetServer::bind(Arc::clone(&engine), "127.0.0.1:0").unwrap();
    let addr = server.local_addr().to_string();

    let before = engine.accepted();
    // Churn: several abrupt deaths in a row, so a leaked reader or
    // writer thread from any one of them would surface.
    for k in 0..8u64 {
        let mut s = TcpStream::connect(&addr).unwrap();
        let hdr = raw_header(FrameKind::Submit, 0, 2, 1000 + k, (ELEMS * 4) as u32);
        s.write_all(&hdr).unwrap();
        // Half the image, then the connection just disappears.
        s.write_all(&vec![0u8; ELEMS * 2]).unwrap();
        drop(s);
    }
    // The listener must still accept and serve a well-formed request.
    roundtrip_serves(&addr, 200);
    assert_eq!(
        engine.accepted(),
        before + 1,
        "partial submits never reached the engine; the follow-up did"
    );

    server.shutdown().unwrap();
    if let Ok(mut e) = Arc::try_unwrap(engine) {
        e.shutdown().unwrap();
    }
}

#[test]
fn wire_responses_are_bit_identical_to_in_process_submission() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let px = pixels();

    // In-process reference: one request through Engine::submit. Both
    // engines use the same small deadline: each serves the request as a
    // single-request batch (drain-flushed in-process, deadline-flushed
    // over the wire), so the sim metering prices the identical batch.
    let reference = {
        let engine = engine_with(Duration::from_millis(5));
        engine
            .submit(InferenceRequest {
                id: 42,
                model: Model::LeNet,
                image: px.as_slice().into(),
                variant: Variant::Int4,
                arrival: Instant::now(),
                deadline: None,
                reply: None,
            })
            .unwrap();
        engine.drain().unwrap();
        let r = engine.responses().pop().unwrap();
        if let Ok(mut e) = Arc::try_unwrap(engine) {
            e.shutdown().unwrap();
        }
        r
    };

    // The same request over the socket, against an identical engine.
    let engine = engine_with(Duration::from_millis(5));
    let server = NetServer::bind(Arc::clone(&engine), "127.0.0.1:0").unwrap();
    let mut client = NetClient::connect(&server.local_addr().to_string()).unwrap();
    client.submit(42, Model::LeNet, Variant::Int4, &px).unwrap();
    match client.recv().unwrap() {
        NetReply::Response(r) => {
            assert_eq!(r.id, reference.id);
            assert_eq!(r.model, reference.model);
            assert_eq!(r.predicted, reference.predicted);
            let wire_bits: Vec<u32> = r.logits.iter().map(|v| v.to_bits()).collect();
            let ref_bits: Vec<u32> =
                reference.logits.as_slice().iter().map(|v| v.to_bits()).collect();
            assert_eq!(wire_bits, ref_bits, "logits must survive the wire bit-exactly");
            assert_eq!(
                r.sim.hw_latency_ms.raw().to_bits(),
                reference.sim.hw_latency_ms.raw().to_bits()
            );
            assert_eq!(
                r.sim.hw_contended_ms.raw().to_bits(),
                reference.sim.hw_contended_ms.raw().to_bits()
            );
            assert_eq!(
                r.sim.hw_energy_mj.raw().to_bits(),
                reference.sim.hw_energy_mj.raw().to_bits()
            );
        }
        other => panic!("expected a response, got {other:?}"),
    }
    client.drain().unwrap();
    assert!(matches!(client.recv().unwrap(), NetReply::Fin));
    server.shutdown().unwrap();
    if let Ok(mut e) = Arc::try_unwrap(engine) {
        e.shutdown().unwrap();
    }
}
