//! Property-based tests over the coordinator/simulator invariants.
//!
//! proptest is unavailable in this offline environment, so these use the
//! in-repo deterministic PRNG with many random cases per property — the
//! same randomized-invariant methodology, with seeds printed on failure.

use opima::cnn::layer::{Layer, TensorShape};
use opima::cnn::Model;
use opima::config::{Geometry, OpimaConfig};
use opima::coordinator::batcher::DynamicBatcher;
use opima::coordinator::request::{InferenceRequest, Variant};
use opima::coordinator::router::Router;
use opima::memory::address::AddressMap;
use opima::memory::cell::{bytes_to_levels, levels_to_bytes};
use opima::memory::MemoryController;
use opima::pim::tdm;
use opima::util::json::Json;
use opima::util::prng::Rng;
use opima::util::units::{ms, ns, Millis};

const CASES: usize = 300;

/// PROPERTY: address decode is total, in-bounds, and row-encode-invertible
/// for every address in capacity.
#[test]
fn prop_address_decode_bijective() {
    let geoms = [
        Geometry::default(),
        Geometry {
            banks: 2,
            subarray_rows: 8,
            subarray_cols: 4,
            rows_per_subarray: 16,
            cols_per_subarray: 32,
            bits_per_cell: 4,
            subarray_groups: 4,
            mdm_degree: 4,
        },
        Geometry {
            banks: 1,
            subarray_rows: 4,
            subarray_cols: 4,
            rows_per_subarray: 8,
            cols_per_subarray: 16,
            bits_per_cell: 2,
            subarray_groups: 2,
            mdm_degree: 4,
        },
    ];
    for (gi, geom) in geoms.iter().enumerate() {
        geom.validate().unwrap();
        let map = AddressMap::new(geom);
        let mut rng = Rng::new(1000 + gi as u64);
        let bpr = map.bytes_per_row() as u64;
        for case in 0..CASES {
            let row_addr = (rng.next_u64() % (map.capacity_bytes() / bpr)) * bpr;
            let d = map.decode(row_addr).unwrap_or_else(|e| {
                panic!("geom {gi} case {case}: decode({row_addr}) failed: {e}")
            });
            assert!(d.bank < geom.banks);
            assert!(d.subarray_row < geom.subarray_rows);
            assert!(d.subarray_col < geom.subarray_cols);
            assert!(d.row < geom.rows_per_subarray);
            assert_eq!(
                map.encode_row(&d),
                row_addr,
                "geom {gi} case {case}: row roundtrip"
            );
        }
    }
}

/// PROPERTY: memory write/read round-trips arbitrary payloads at
/// arbitrary (aligned) addresses, including overlapping rewrites.
#[test]
fn prop_memory_roundtrip_random() {
    let cfg = OpimaConfig::paper();
    let mut mem = MemoryController::new(&cfg).unwrap();
    let mut rng = Rng::new(7);
    let cap = mem.capacity_bytes();
    // Shadow model over a confined window so overlaps actually happen.
    let window = 1u64 << 16;
    let base = (rng.next_u64() % (cap - 2 * window)) / 16 * 16;
    let mut shadow = vec![0u8; window as usize];
    for case in 0..CASES {
        let len = 1 + rng.index(512);
        let off = rng.index(window as usize - len);
        let aligned_off = off / 2 * 2; // cell alignment (4-bit cells)
        let data: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
        mem.write(base + aligned_off as u64, &data)
            .unwrap_or_else(|e| panic!("case {case}: write: {e}"));
        shadow[aligned_off..aligned_off + len].copy_from_slice(&data);
        // Random readback window.
        let rlen = 1 + rng.index(512);
        let roff = rng.index(window as usize - rlen);
        let got = mem
            .read(base + roff as u64, rlen as u64)
            .unwrap()
            .data
            .unwrap();
        assert_eq!(
            got,
            &shadow[roff..roff + rlen],
            "case {case}: read window mismatch"
        );
    }
}

/// PROPERTY: level packing/unpacking is a bijection for every density.
#[test]
fn prop_levels_roundtrip() {
    let mut rng = Rng::new(13);
    for _ in 0..CASES {
        let len = 1 + rng.index(128);
        let bytes: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
        for bits in [1u32, 2, 4, 8] {
            let levels = bytes_to_levels(&bytes, bits);
            assert!(levels.iter().all(|&l| (l as u32) < (1 << bits)));
            assert_eq!(levels_to_bytes(&levels, bits), bytes);
        }
    }
}

/// PROPERTY: the batcher never loses or duplicates a request, never
/// exceeds the batch size, and never mixes variants — or models.
#[test]
fn prop_batcher_conservation() {
    let mut rng = Rng::new(21);
    let models = [Model::LeNet, Model::ResNet18, Model::Vgg16];
    for case in 0..50 {
        let max_batch = 1 + rng.index(16);
        let n = 1 + rng.index(200);
        let mut b = DynamicBatcher::new(max_batch, std::time::Duration::from_secs(3600));
        let mut seen = Vec::new();
        for id in 0..n as u64 {
            let variant = match rng.index(3) {
                0 => Variant::Fp32,
                1 => Variant::Int8,
                _ => Variant::Int4,
            };
            if let Some(batch) = b.push(InferenceRequest {
                id,
                model: models[rng.index(models.len())],
                image: vec![].into(),
                variant,
                arrival: std::time::Instant::now(),
                deadline: None,
                reply: None,
            }) {
                assert!(batch.requests.len() <= max_batch, "case {case}");
                assert!(
                    batch.requests.iter().all(|r| r.variant == batch.variant),
                    "case {case}: mixed variants"
                );
                assert!(
                    batch.requests.iter().all(|r| r.model == batch.model),
                    "case {case}: mixed models"
                );
                seen.extend(batch.requests.iter().map(|r| r.id));
            }
        }
        for batch in b.drain() {
            assert!(batch.requests.len() <= max_batch);
            assert!(batch.requests.iter().all(|r| r.model == batch.model));
            seen.extend(batch.requests.iter().map(|r| r.id));
        }
        seen.sort();
        assert_eq!(
            seen,
            (0..n as u64).collect::<Vec<_>>(),
            "case {case}: conservation"
        );
        assert_eq!(b.pending(), 0);
    }
}

/// PROPERTY: the router conserves work, never double-books an instance,
/// and its makespan is bounded by total/instances ≤ makespan ≤ total.
#[test]
fn prop_router_work_conservation() {
    let mut rng = Rng::new(33);
    for case in 0..CASES {
        let instances = 1 + rng.index(8);
        let mut r = Router::new(instances);
        let n = 1 + rng.index(100);
        let mut total = 0.0f64;
        let mut intervals: Vec<Vec<(f64, f64)>> = vec![Vec::new(); instances];
        for _ in 0..n {
            let dur = 0.1 + rng.f64() * 10.0;
            total += dur;
            let (idx, start, end) = r.dispatch(Millis::ZERO, ms(dur));
            assert!((end - start - ms(dur)).abs().raw() < 1e-9);
            intervals[idx].push((start.raw(), end.raw()));
        }
        // No overlapping reservations per instance.
        for (i, iv) in intervals.iter_mut().enumerate() {
            iv.sort_by(|a, b| a.0.total_cmp(&b.0));
            for w in iv.windows(2) {
                assert!(
                    w[0].1 <= w[1].0 + 1e-9,
                    "case {case}: instance {i} overlap {w:?}"
                );
            }
        }
        let makespan = r.makespan_ms().raw();
        assert!(makespan <= total + 1e-6, "case {case}");
        assert!(
            makespan + 1e-6 >= total / instances as f64,
            "case {case}: makespan {makespan} < ideal {}",
            total / instances as f64
        );
        assert_eq!(r.load().iter().sum::<u64>(), n as u64);
    }
}

/// PROPERTY: TDM plans are exact multiplicative decompositions.
#[test]
fn prop_tdm_plan_consistency() {
    let mut rng = Rng::new(55);
    for _ in 0..CASES {
        let cell = [1u32, 2, 4, 8][rng.index(4)];
        let act = cell * (1 + rng.index(8) as u32);
        let weight = cell * (1 + rng.index(8) as u32);
        let p = tdm::plan(act, weight, cell).unwrap();
        assert_eq!(p.steps, p.act_digits * p.weight_digits);
        assert_eq!(p.act_digits * cell, act);
        assert_eq!(p.weight_digits * cell, weight);
        assert_eq!(p.shift_adds, p.steps - 1);
    }
}

/// PROPERTY: conv layer shape algebra — output fits, params and MACs are
/// consistent (macs = out_elems × k² × cin/groups).
#[test]
fn prop_conv_shape_algebra() {
    let mut rng = Rng::new(77);
    let mut checked = 0;
    for _ in 0..CASES {
        let h = 4 + rng.index(40);
        let c = 1 + rng.index(64);
        let k = [1usize, 3, 5, 7][rng.index(4)];
        let stride = 1 + rng.index(2);
        let cout = 1 + rng.index(128);
        let layer = Layer::Conv {
            kh: k,
            kw: k,
            cout,
            stride,
            pad: k / 2,
            groups: 1,
            bias: true,
        };
        let input = TensorShape::new(h, h, c);
        let Ok(out) = layer.out_shape(input) else {
            continue;
        };
        checked += 1;
        let macs = layer.macs(input).unwrap();
        assert_eq!(macs, out.elems() * (k * k * c) as u64);
        assert_eq!(layer.params(input), (k * k * c * cout + cout) as u64);
        assert!(out.h >= 1 && out.w >= 1);
    }
    assert!(checked > CASES / 2);
}

/// PROPERTY: JSON printer/parser round-trips random documents.
#[test]
fn prop_json_roundtrip_fuzz() {
    fn gen(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.index(4) } else { rng.index(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.index(2) == 0),
            2 => Json::Num((rng.next_u64() % 1_000_000) as f64 / 8.0),
            3 => Json::Str(format!("s{}\"\\\n{}", rng.index(100), rng.index(100))),
            4 => Json::Arr((0..rng.index(5)).map(|_| gen(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.index(5))
                    .map(|i| (format!("k{i}"), gen(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    let mut rng = Rng::new(99);
    for case in 0..CASES {
        let v = gen(&mut rng, 3);
        let text = v.to_string();
        let back = Json::parse(&text)
            .unwrap_or_else(|e| panic!("case {case}: reparse failed: {e}\n{text}"));
        assert_eq!(back, v, "case {case}");
    }
}

/// PROPERTY: random valid configs round-trip through TOML and keep
/// validating.
#[test]
fn prop_config_toml_roundtrip_random() {
    let mut rng = Rng::new(111);
    for case in 0..100 {
        let mut cfg = OpimaConfig::paper();
        cfg.geometry.banks = 1 + rng.index(4);
        cfg.geometry.mdm_degree = cfg.geometry.banks.max(1 + rng.index(4));
        if cfg.geometry.mdm_degree > 4 {
            cfg.geometry.mdm_degree = 4;
        }
        if cfg.geometry.banks > cfg.geometry.mdm_degree {
            cfg.geometry.banks = cfg.geometry.mdm_degree;
        }
        let rows = [16usize, 32, 64][rng.index(3)];
        cfg.geometry.subarray_rows = rows;
        let divisors: Vec<usize> = (1..=rows).filter(|g| rows % g == 0).collect();
        cfg.geometry.subarray_groups = divisors[rng.index(divisors.len())];
        cfg.timing.clock_ghz = 1.0 + rng.f64() * 9.0;
        cfg.timing.write_ns = cfg.timing.read_ns + ns(rng.f64() * 2000.0);
        cfg.validate().unwrap_or_else(|e| panic!("case {case}: {e}"));
        let text = cfg.to_toml();
        let back = OpimaConfig::from_toml(&text)
            .unwrap_or_else(|e| panic!("case {case}: parse: {e}"));
        assert_eq!(cfg, back, "case {case}");
    }
}
