//! Streaming-stats invariants: histogram percentile accuracy against the
//! exact sorted-vec oracle, and bounded sink memory under a soak load
//! that far exceeds the response ring's capacity.
//!
//! proptest is unavailable in this offline environment, so the property
//! test uses the in-repo deterministic PRNG with the seed printed in the
//! assertion message — the same randomized-invariant methodology as
//! `rust/tests/properties.rs`.

use std::time::{Duration, Instant};

use opima::cnn::Model;
use opima::coordinator::engine::{Engine, EngineConfig};
use opima::coordinator::request::{InferenceRequest, Variant};
use opima::runtime::{ExecutorSpec, Manifest};
use opima::util::histogram::{nearest_rank, Histogram};
use opima::util::prng::Rng;

/// PROPERTY: for any sample set, histogram percentiles match the exact
/// nearest-rank (`ceil(p·n) - 1`) sorted-vec percentile within the
/// bucketing's relative-error bound, at n ∈ {1, 2, 10, 10_000}; and the
/// streaming mean/min/max are exact.
#[test]
fn prop_histogram_percentiles_match_exact_oracle() {
    for &n in &[1usize, 2, 10, 10_000] {
        for seed in 0..5u64 {
            let mut rng = Rng::new(7700 + seed);
            // Log-normal-ish samples spanning several orders of
            // magnitude — the shape of real latency tails.
            let vals: Vec<f64> = (0..n).map(|_| (rng.normal() * 1.5).exp()).collect();
            let mut h = Histogram::new();
            for &v in &vals {
                h.record(v);
            }
            let mut sorted = vals.clone();
            sorted.sort_by(f64::total_cmp);
            for &p in &[0.5, 0.9, 0.99, 0.999] {
                let exact = nearest_rank(&sorted, p);
                let est = h.percentile(p);
                assert!(
                    (est - exact).abs() <= exact * Histogram::MAX_REL_ERROR + 1e-12,
                    "n={n} seed={seed} p={p}: est {est} vs exact {exact}"
                );
            }
            let mean = vals.iter().sum::<f64>() / n as f64;
            let s = h.summary();
            assert_eq!(s.count, n as u64);
            assert!((s.mean - mean).abs() <= mean * 1e-12, "mean is exact");
            assert_eq!(s.min, sorted[0], "min is exact");
            assert_eq!(s.max, sorted[n - 1], "max is exact");
        }
    }
}

/// Regression for the seed's `totals[n / 2]` off-by-one: at n=2 the p50
/// must track the *lower* sample (nearest-rank ceil(0.5·2) = 1), not
/// the max.
#[test]
fn p50_of_two_samples_is_the_lower_one() {
    let mut h = Histogram::new();
    h.record(1.0);
    h.record(1000.0);
    assert!(h.percentile(0.5) < 1.01, "p50 {}", h.percentile(0.5));
    assert_eq!(nearest_rank(&[1.0, 1000.0], 0.5), 1.0);
}

fn req(id: u64) -> InferenceRequest {
    let variant = match id % 3 {
        0 => Variant::Fp32,
        1 => Variant::Int8,
        _ => Variant::Int4,
    };
    InferenceRequest {
        id,
        model: Model::LeNet,
        image: (0..144).map(|i| ((id as usize + i) % 11) as f32 * 0.1).collect(),
        variant,
        arrival: Instant::now(),
        deadline: None,
        reply: None,
    }
}

/// SOAK: after N ≫ ring-capacity responses the sink retains only
/// `history` responses, while `stats()` still reports aggregates
/// (served count, means, percentiles, energy) over *all* N — i.e. sink
/// memory is O(capacity) and statistics are lossless.
#[test]
fn soak_sink_memory_bounded_stats_complete() {
    const HISTORY: usize = 64;
    const N: u64 = 2048;
    let mut e = Engine::new(
        EngineConfig {
            workers: 2,
            queue_capacity: 128,
            instances: 2,
            max_wait: Duration::from_millis(1),
            executor: ExecutorSpec::Sim { work_factor: 1 },
            history: HISTORY,
            ..EngineConfig::default()
        },
        Manifest::synthetic(8, 12),
    )
    .unwrap();
    for id in 0..N {
        e.submit_blocking(req(id)).unwrap();
    }
    e.drain().unwrap();

    // Retention is exactly the ring capacity — 32× fewer than served.
    let retained = e.responses();
    assert_eq!(retained.len(), HISTORY, "sink memory is O(capacity)");
    let (tail, cursor) = e.responses_since(0);
    assert_eq!(cursor, N, "every response got a completion sequence");
    assert_eq!(tail.len(), HISTORY, "only the ring tail is retrievable");

    // Aggregates still cover all N responses.
    let s = e.stats();
    assert_eq!(s.served, N);
    assert_eq!(s.failed, 0);
    assert_eq!(s.latency.total.count, N);
    assert_eq!(s.latency.queue.count, N);
    assert!(s.batches >= N / 8);
    assert!(s.sim_energy_mj.raw() > 0.0);
    // Percentiles are present, ordered, and inside the observed range.
    assert!(s.latency.total.p50 > 0.0);
    assert!(s.latency.total.p50 <= s.latency.total.p90 + 1e-12);
    assert!(s.latency.total.p90 <= s.latency.total.p99 + 1e-12);
    assert!(s.latency.total.p99 <= s.latency.total.p999 + 1e-12);
    assert!(s.latency.total.p999 <= s.latency.total.max + 1e-12);
    assert!(s.latency.total.min <= s.latency.total.p50 + 1e-12);
    // Exact means keep the stage accounting identity: form ≤ queue.
    assert!(s.mean_form_ms <= s.mean_queue_ms + opima::util::units::ms(1e-9));
    e.shutdown().unwrap();
}

/// Tailing with `responses_since` sees each retained response exactly
/// once, and a cursor that fell behind the ring resumes at the live
/// tail instead of stalling.
#[test]
fn responses_since_tails_without_duplicates() {
    const HISTORY: usize = 16;
    let mut e = Engine::new(
        EngineConfig {
            workers: 1,
            queue_capacity: 64,
            instances: 1,
            max_wait: Duration::from_millis(1),
            executor: ExecutorSpec::Sim { work_factor: 1 },
            history: HISTORY,
            ..EngineConfig::default()
        },
        Manifest::synthetic(8, 12),
    )
    .unwrap();
    // First wave fits the ring: the tail consumer sees all of it.
    for id in 0..16 {
        e.submit_blocking(req(3 * id + 2)).unwrap(); // all Int4
    }
    e.drain().unwrap();
    let (first, cursor) = e.responses_since(0);
    assert_eq!(first.len(), 16);
    assert_eq!(cursor, 16);
    // Second wave overflows the ring (32 > 16) while the consumer
    // sleeps: it gets only the retained tail, but the cursor lands on
    // the live head so the next poll is gap-free.
    for id in 16..48 {
        e.submit_blocking(req(3 * id + 2)).unwrap();
    }
    e.drain().unwrap();
    let (second, cursor2) = e.responses_since(cursor);
    assert_eq!(second.len(), HISTORY, "evicted gap is lost, tail is not");
    assert_eq!(cursor2, 48);
    let (third, _) = e.responses_since(cursor2);
    assert!(third.is_empty(), "caught-up consumer sees nothing new");
    e.shutdown().unwrap();
}
