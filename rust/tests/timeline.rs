//! Property tests over the pipelined simulation timeline.
//!
//! The invariants the refactor promises (ISSUE 4):
//! - pipelined batch latency ≥ the bottleneck-stage lower bound
//!   (`max_stage × images`, generalized per resource pool),
//! - pipelined batch latency ≤ the sequential `batch ×` sum,
//! - makespan is monotone in batch size,
//! - batch = 1 equals the analytical `ModelAnalysis` totals exactly,
//! - multi-row-kernel models at batch ≥ 8 are *strictly* sublinear.
//!
//! proptest is unavailable offline, so these use the in-repo
//! deterministic PRNG with many random cases (seeds printed on failure).

use opima::analyzer::latency::analyze_model;
use opima::analyzer::timeline::simulate_analysis;
use opima::cnn::graph::{Network, NetworkBuilder};
use opima::cnn::layer::TensorShape;
use opima::cnn::{build_model, Model};
use opima::util::prng::Rng;
use opima::util::units::{ns, Nanos};
use opima::OpimaConfig;

/// Build a random small CNN: a few conv/pool stages and an FC head.
fn random_net(rng: &mut Rng, case: usize) -> Network {
    let side = 8 + 4 * rng.index(4); // 8..20
    let cin = 1 + rng.index(3);
    let mut b = NetworkBuilder::new(&format!("rand{case}"), TensorShape::new(side, side, cin));
    let stages = 1 + rng.index(3);
    for _ in 0..stages {
        let k = [1usize, 3, 3, 5][rng.index(4)];
        let cout = 4 << rng.index(3);
        b.conv(k, k, cout, 1, k / 2).unwrap();
        if rng.index(2) == 0 {
            b.pool(2, 2).unwrap();
        }
    }
    b.fc(1 + rng.index(16)).unwrap();
    b.build()
}

#[test]
fn prop_timeline_bounds_hold_for_random_nets() {
    let cfg = OpimaConfig::paper();
    let mut rng = Rng::new(4040);
    for case in 0..40 {
        let net = random_net(&mut rng, case);
        let bits = [4u32, 8][rng.index(2)];
        let a = analyze_model(&cfg, &net, bits).unwrap();
        let batch = 1 + rng.index(24);
        let t = simulate_analysis(&cfg, &a, batch);
        assert_eq!(t.batch, batch);
        let seq = a.total_ms().to_nanos() * batch as f64;
        assert!(
            (t.sequential_ns - seq).abs() <= 1e-9 * seq,
            "case {case}: sequential mismatch"
        );
        assert!(
            t.makespan_ns <= t.sequential_ns * (1.0 + 1e-12),
            "case {case}: makespan {} exceeds sequential {}",
            t.makespan_ns,
            t.sequential_ns
        );
        assert!(
            t.makespan_ns + ns(1e-6) >= t.bottleneck_ns,
            "case {case}: makespan {} beats the bottleneck bound {}",
            t.makespan_ns,
            t.bottleneck_ns
        );
        // The bound itself is at least the busiest single stage × batch.
        let max_stage = a
            .layer_costs
            .iter()
            .map(|c| (c.mac_ns + c.aggregation_ns).max(c.writeback_ns))
            .fold(Nanos::ZERO, |acc, v| acc.max(v));
        assert!(
            t.bottleneck_ns + ns(1e-6) >= max_stage * batch as f64,
            "case {case}: bottleneck below max_stage × images"
        );
    }
}

#[test]
fn prop_batch_one_matches_analytical_totals() {
    let cfg = OpimaConfig::paper();
    let mut rng = Rng::new(1111);
    for case in 0..40 {
        let net = random_net(&mut rng, case);
        let bits = [4u32, 8][rng.index(2)];
        let a = analyze_model(&cfg, &net, bits).unwrap();
        let t = simulate_analysis(&cfg, &a, 1);
        let total_ns = a.total_ms().to_nanos();
        assert!(
            (t.makespan_ns - total_ns).abs() <= 1e-9 * total_ns.max(ns(1.0)),
            "case {case}: batch-1 timeline {} != analytical {}",
            t.makespan_ns,
            total_ns
        );
    }
}

#[test]
fn prop_makespan_monotone_in_batch() {
    let cfg = OpimaConfig::paper();
    let mut rng = Rng::new(2222);
    for case in 0..20 {
        let net = random_net(&mut rng, case);
        let a = analyze_model(&cfg, &net, 4).unwrap();
        let mut prev = Nanos::ZERO;
        for batch in [1usize, 2, 3, 5, 8, 13, 21] {
            let t = simulate_analysis(&cfg, &a, batch);
            assert!(
                t.makespan_ns >= prev - ns(1e-9),
                "case {case}: batch {batch} shrank the makespan"
            );
            prev = t.makespan_ns;
        }
    }
}

#[test]
fn multi_row_kernel_models_batch8_strictly_sublinear() {
    // The acceptance criterion: for a multi-row-kernel model at
    // batch ≥ 8, pipelined batch latency is strictly below `batch ×`
    // the single-inference latency while respecting the bottleneck
    // bound. ResNet18 and VGG16 are the paper's multi-row-kernel CNNs.
    let cfg = OpimaConfig::paper();
    for model in [Model::ResNet18, Model::Vgg16] {
        let a = analyze_model(&cfg, &build_model(model).unwrap(), 4).unwrap();
        for batch in [8usize, 16] {
            let t = simulate_analysis(&cfg, &a, batch);
            assert!(t.pipelined);
            let linear = batch as f64 * a.total_ms().to_nanos();
            assert!(
                t.makespan_ns < linear,
                "{model:?} batch {batch}: {} !< {linear}",
                t.makespan_ns
            );
            assert!(t.makespan_ns + ns(1e-3) >= t.bottleneck_ns);
            assert!(t.speedup() > 1.0);
        }
    }
}

#[test]
fn registry_timeline_agrees_with_direct_simulation() {
    // The serving registry's cached timelines must be the same schedule
    // the analyzer computes directly.
    use opima::coordinator::registry::{augment_manifest, PlanRegistry};
    use opima::coordinator::request::Variant;
    use opima::runtime::Manifest;

    let cfg = OpimaConfig::paper();
    let mut manifest = Manifest::synthetic(8, 12);
    augment_manifest(&mut manifest);
    let registry = PlanRegistry::new(cfg.clone(), manifest);
    let cached = registry.timeline(Model::ResNet18, Variant::Int4, 16).unwrap();
    let a = analyze_model(&cfg, &build_model(Model::ResNet18).unwrap(), 4).unwrap();
    let direct = simulate_analysis(&cfg, &a, 16);
    assert!((cached.makespan_ns - direct.makespan_ns).abs() <= 1e-9 * direct.makespan_ns);
    assert_eq!(cached.batch, 16);
}
