#!/usr/bin/env python3
"""Bench-regression gate over the BENCH_<name>.json summaries.

Usage: bench_gate.py <baseline_dir> <current_dir>

Compares every committed baseline summary in <baseline_dir> against the
freshly produced counterpart in <current_dir>, row by row (matched by
the row's "name"). A row regresses when its current mean_ns exceeds
baseline mean_ns x OPIMA_BENCH_TOL (default 5.0 -- generous on purpose:
CI machines vary and the smoke runs take one sample, so only
order-of-magnitude rot should trip the gate). Sub-microsecond baseline
rows are skipped outright: at that scale a single sample is timer
noise, not signal.

When the current hotpath summary is a full (non-smoke) run, the ISSUE 6
acceptance bound is also enforced: global-engine dispatch
(router/dispatch_batch_contended_1k) must land within 2x of the
occupancy-only router (router/dispatch_for_occupancy_1k). Likewise the
ISSUE 8 writeback-model ordering: the deterministic simulated makespans
in the memory/writeback_model_makespan value row must satisfy
scheduled_ns <= naive_ns (the scheduled controller only relaxes the
naive reference's constraints, so a violation means a controller bug,
not machine noise).

Exit status: 0 clean, 1 regression (or malformed/missing summaries).
"""

import json
import os
import sys

TOL = float(os.environ.get("OPIMA_BENCH_TOL", "5.0"))
# Baseline rows faster than this are single-sample timer noise; skip.
MIN_BASELINE_NS = 1000.0
# ISSUE 6 acceptance: contended dispatch within 2x of occupancy-only.
DISPATCH_BOUND = 2.0
DISPATCH_CONTENDED = "router/dispatch_batch_contended_1k"
DISPATCH_OCCUPANCY = "router/dispatch_for_occupancy_1k"
# ISSUE 8 acceptance: scheduled writeback never prices above naive.
WRITEBACK_MAKESPAN = "memory/writeback_model_makespan"


def load(path):
    with open(path) as f:
        return json.load(f)


def rows_by_name(doc):
    return {r["name"]: r for r in doc.get("results", []) if "name" in r}


def main():
    if len(sys.argv) != 3:
        print(__doc__.strip())
        return 1
    baseline_dir, current_dir = sys.argv[1], sys.argv[2]
    names = sorted(
        f for f in os.listdir(baseline_dir)
        if f.startswith("BENCH_") and f.endswith(".json")
    )
    if not names:
        print("bench_gate: no baselines in", baseline_dir, "- nothing to gate")
        return 0
    failures = []
    for name in names:
        base = load(os.path.join(baseline_dir, name))
        cur_path = os.path.join(current_dir, name)
        if not os.path.exists(cur_path):
            failures.append(f"{name}: baseline exists but current run produced no summary")
            continue
        cur = load(cur_path)
        cur_rows = rows_by_name(cur)
        for row_name, b in sorted(rows_by_name(base).items()):
            b_mean = b.get("mean_ns")
            if b_mean is None:
                continue  # non-timing row (e.g. req_per_s); not gated
            c = cur_rows.get(row_name)
            if c is None:
                failures.append(f"{name}: row '{row_name}' vanished from the current run")
                continue
            c_mean = c.get("mean_ns")
            if c_mean is None or b_mean < MIN_BASELINE_NS:
                continue
            ratio = c_mean / b_mean
            verdict = "FAIL" if ratio > TOL else "ok"
            print(f"bench_gate: {row_name:<48} {b_mean:>14.0f} -> {c_mean:>14.0f} ns "
                  f"({ratio:.2f}x, tol {TOL:.1f}x) {verdict}")
            if ratio > TOL:
                failures.append(f"{name}: '{row_name}' regressed {ratio:.2f}x (> {TOL:.1f}x)")
        # The contended-vs-occupancy dispatch bound, on trustworthy
        # (non-smoke) hotpath numbers only.
        if name == "BENCH_hotpath.json" and not cur.get("smoke", True):
            con = cur_rows.get(DISPATCH_CONTENDED, {}).get("mean_ns")
            occ = cur_rows.get(DISPATCH_OCCUPANCY, {}).get("mean_ns")
            if con and occ:
                ratio = con / occ
                print(f"bench_gate: contended/occupancy dispatch ratio {ratio:.2f}x "
                      f"(bound {DISPATCH_BOUND:.1f}x)")
                if ratio > DISPATCH_BOUND:
                    failures.append(
                        f"{name}: contended dispatch {ratio:.2f}x occupancy-only "
                        f"(bound {DISPATCH_BOUND:.1f}x)")
        if name == "BENCH_hotpath.json" and not cur.get("smoke", True):
            wb = cur_rows.get(WRITEBACK_MAKESPAN, {})
            naive, sched = wb.get("naive_ns"), wb.get("scheduled_ns")
            if naive is not None and sched is not None:
                print(f"bench_gate: writeback makespan naive {naive:.0f} ns, "
                      f"scheduled {sched:.0f} ns")
                if sched > naive:
                    failures.append(
                        f"{name}: scheduled writeback makespan {sched:.0f} ns "
                        f"above naive {naive:.0f} ns")
    for f in failures:
        print("bench_gate: FAIL:", f)
    if not failures:
        print("bench_gate: OK")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
