// Known-bad fixture for `scripts/lint_invariants.py --self-test`.
// Every rule must fire at least once on this file. It is NOT part of
// the crate (lives outside rust/src) and is never compiled.

use std::sync::Mutex;
use std::time::Instant;

// [units-f64] quantity-suffixed f64 field instead of a units newtype.
pub struct BadSummary {
    pub makespan_ns: f64,
    pub energy_mj: f64,
}

// [units-f64] suffixed f64 params, by value and by reference.
fn bad_admit(window_ms: f64, budget_mw: &mut f64) -> f64 {
    // [time-literal] ad-hoc ms->ns conversion outside units.rs.
    window_ms * 1e6 + *budget_mw * 1e-6
}

fn bad_lock(shared: &Mutex<u64>) -> u64 {
    // [lock-unwrap] panics forever on a poisoned lock.
    *shared.lock().unwrap()
}

fn bad_lock_expect(shared: &Mutex<u64>) -> u64 {
    // [lock-unwrap] expect is no better.
    *shared.lock().expect("poisoned")
}

fn bad_clock() -> Instant {
    // [instant] wall-clock read (fixture is posed under analyzer/).
    Instant::now()
}

// [nanos-literal] bare duration literals minted outside timing.rs
// (fixture is also posed under memory/ — device timing constants live
// in memory/timing.rs only).
const BAD_SETTLE: Nanos = Nanos::new(42.0);

fn bad_settle_budget() -> Nanos {
    ns(10.0)
}

// [frame-copy] payload copies minted on the wire path (fixture is also
// posed under coordinator/net/ — decode into pooled buffers instead).
fn bad_decode(payload: &[u8]) -> Vec<u8> {
    payload.to_vec()
}

fn bad_decode_from(payload: &[u8]) -> Vec<u8> {
    Vec::from(payload)
}

// [thread-spawn] a detached serving thread: nobody joins or supervises
// the handle (fixture is also posed under coordinator/net/ — use a
// named Builder thread joined on shutdown, a scoped thread, or a
// same-line allow naming the supervisor).
fn bad_detached_worker() {
    std::thread::spawn(|| loop {});
}
