#!/usr/bin/env python3
"""Repo invariant linter: static rules the Rust type system can't carry.

The units layer (rust/src/util/units.rs) makes ns/ms/mJ confusion a
compile error wherever quantities are *typed* — this linter closes the
residual conventions around it:

  units-f64     No f64 field/param whose name ends in _ns/_ms/_mj/_mw
                outside util/units.rs. New quantity-bearing declarations
                must use the newtypes (Nanos/Millis/Millijoules/
                Milliwatts), not the old naming convention.
  time-literal  No bare 1e6 / 1e-6 time-conversion literal outside
                util/units.rs. All ns<->ms conversions must route
                through Nanos::to_millis / Millis::to_nanos so the
                factor exists in exactly one place.
  lock-unwrap   No .unwrap()/.expect() directly on lock()/read()/write()
                results in rust/src non-test code. Use the poisoned-lock
                idiom (unwrap_or_else(PoisonError::into_inner), see
                coordinator/engine.rs) so a panicked worker can't wedge
                the server.
  instant       No Instant::now() inside rust/src/analyzer/ — simulated
                time must never read the wall clock.
  nanos-literal No Nanos built from a bare numeric literal (Nanos::new(3.0)
                or ns(10.0)) inside rust/src/memory/ outside timing.rs —
                OPCM device timing constants (GST reconfig, pulse widths)
                live in timing.rs only, so a device-parameter change is
                one edit, not a hunt.
  frame-copy    No .to_vec()/Vec::from inside rust/src/coordinator/net/ —
                the wire path's <1-allocation-per-request budget (ISSUE 9)
                forbids copying frame payloads into fresh Vecs; decode
                into pooled buffers / reused scratch instead.
  thread-spawn  No bare std::thread::spawn inside rust/src/coordinator/ —
                a detached serving thread is an unsupervised failure
                domain (ISSUE 10). Threads must be owned: named
                Builder::new().spawn handles joined on shutdown, scoped
                threads, or a same-line `// lint: allow(thread-spawn)`
                stating who joins/supervises the handle.

Scope and escape hatches:
  * Only rust/src/**/*.rs is scanned (benches, examples, rust/tests and
    scripts are out of scope — tests legitimately poke raw scalars).
  * Lines after a `#[cfg(test)]` marker in a file are skipped: in this
    repo, test modules sit at the bottom of each source file.
  * A line carrying `// lint: allow(<rule>)` is exempt from <rule>.
    Each allow should carry an in-line justification.

Stdlib-only and line-oriented by design: no rustc, no pip, no parsing —
it must run first in ci.sh, before anything is built.

Usage:
  python3 scripts/lint_invariants.py               lint the tree
  python3 scripts/lint_invariants.py --self-test   verify rules fire on
                                                   the known-bad fixture
"""

import re
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent
SRC_ROOT = REPO_ROOT / "rust" / "src"
UNITS_FILE = SRC_ROOT / "util" / "units.rs"
FIXTURE = REPO_ROOT / "scripts" / "lint_fixtures" / "known_bad.rs"

TEST_MARKER = "#[cfg(test)]"
ALLOW_RE = re.compile(r"//\s*lint:\s*allow\(([a-z0-9_,\s-]+)\)")


def in_analyzer(path: Path) -> bool:
    return "analyzer" in path.parts


def in_memory_not_timing(path: Path) -> bool:
    return "memory" in path.parts and path.name != "timing.rs"


def in_coordinator_net(path: Path) -> bool:
    return "coordinator" in path.parts and "net" in path.parts


def in_coordinator(path: Path) -> bool:
    return "coordinator" in path.parts


def not_units(path: Path) -> bool:
    return path.name != "units.rs" or path.parent.name != "util"


# Each rule: (name, compiled regex, file predicate, human message).
RULES = [
    (
        "units-f64",
        re.compile(r"\b\w+_(?:ns|ms|mj|mw)\s*:\s*&?(?:mut\s+)?f64\b"),
        not_units,
        "quantity-suffixed f64 declaration — use Nanos/Millis/Millijoules/"
        "Milliwatts from util/units.rs",
    ),
    (
        "time-literal",
        re.compile(r"(?<![\w.])1e-?6(?![\d._])"),
        not_units,
        "bare 1e6/1e-6 time-conversion literal — route through "
        "Nanos::to_millis / Millis::to_nanos",
    ),
    (
        "lock-unwrap",
        re.compile(r"\.(?:lock|read|write)\(\)\s*\.\s*(?:unwrap|expect)\s*\("),
        lambda path: True,
        "unwrap/expect on a lock result — use the poisoned-lock idiom "
        "(unwrap_or_else(PoisonError::into_inner))",
    ),
    (
        "instant",
        re.compile(r"\bInstant::now\s*\("),
        in_analyzer,
        "wall-clock read inside analyzer/ — simulated time only",
    ),
    (
        # `\bns(` deliberately misses the `_ns(...)` accessor/helper
        # convention: only the bare constructor and the `ns()` literal
        # builder count as minting a duration.
        "nanos-literal",
        re.compile(r"(?:\bNanos::new|\bns)\(\s*[0-9]"),
        in_memory_not_timing,
        "bare numeric Nanos literal inside memory/ — device timing "
        "constants belong in memory/timing.rs",
    ),
    (
        "frame-copy",
        re.compile(r"\.to_vec\(\)|\bVec::from\b"),
        in_coordinator_net,
        "payload copy inside coordinator/net/ — the wire path must decode "
        "into pooled buffers / reused scratch (<1 alloc per request)",
    ),
    (
        # `thread::spawn` only: `thread::Builder::new().spawn` (named,
        # handle-joined) and scoped `s.spawn` don't match and are the
        # sanctioned idioms.
        "thread-spawn",
        re.compile(r"\bthread::spawn\b"),
        in_coordinator,
        "detached thread::spawn inside coordinator/ — serving threads "
        "must be supervised (join the handle on shutdown, use a named "
        "Builder/scoped thread, or allow with who joins it)",
    ),
]


def lint_lines(path: Path, lines, active_rules):
    """Yield (path, lineno, rule, message) for each violation."""
    in_tests = False
    for lineno, line in enumerate(lines, start=1):
        if TEST_MARKER in line:
            in_tests = True
        if in_tests:
            continue
        allow = ALLOW_RE.search(line)
        allowed = set()
        if allow:
            allowed = {r.strip() for r in allow.group(1).split(",")}
        for name, pattern, _, message in active_rules:
            if name in allowed:
                continue
            if pattern.search(line):
                yield (path, lineno, name, message)


def lint_file(path: Path):
    active = [r for r in RULES if r[2](path)]
    if not active:
        return []
    lines = path.read_text(encoding="utf-8").splitlines()
    return list(lint_lines(path, lines, active))


def lint_tree():
    violations = []
    for path in sorted(SRC_ROOT.rglob("*.rs")):
        violations.extend(lint_file(path))
    return violations


def report(violations) -> int:
    for path, lineno, rule, message in violations:
        rel = path.relative_to(REPO_ROOT) if path.is_absolute() else path
        print(f"{rel}:{lineno}: [{rule}] {message}")
    if violations:
        print(f"lint_invariants: {len(violations)} violation(s)", file=sys.stderr)
        return 1
    print("lint_invariants: clean")
    return 0


GOOD_SNIPPET = """\
use crate::util::units::{Millis, Nanos};
pub struct Summary { pub makespan_ns: Nanos, pub window_ms: Millis }
fn admit(window_ms: Millis) -> Nanos { window_ms.to_nanos() }
fn guard(m: &std::sync::Mutex<u32>) -> u32 {
    *m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}
const SCALE: f64 = 1e-3; // non-time scaling literals stay legal
fn shown(pj: f64) -> f64 { pj / 1e6 } // lint: allow(time-literal) pJ->uJ display
#[cfg(test)]
mod tests {
    fn raw(makespan_ns: f64) -> f64 { makespan_ns / 1e6 } // tests exempt
}
"""


def self_test() -> int:
    """The seeded-bad fixture must trip every rule; the good snippet none."""
    ok = True
    if not FIXTURE.is_file():
        print(f"self-test: missing fixture {FIXTURE}", file=sys.stderr)
        return 1
    # The fixture is checked in three poses — as if it lived under
    # rust/src/analyzer/ (arming the analyzer-scoped `instant` rule),
    # under rust/src/memory/ (arming the memory-scoped `nanos-literal`
    # rule) and under rust/src/coordinator/net/ (arming the wire-scoped
    # `frame-copy` rule). Every rule must fire in at least one pose; the
    # known-good snippet must fire in none.
    lines = FIXTURE.read_text(encoding="utf-8").splitlines()
    fired = set()
    for posed in (
        SRC_ROOT / "analyzer" / "known_bad.rs",
        SRC_ROOT / "memory" / "known_bad.rs",
        SRC_ROOT / "coordinator" / "net" / "known_bad.rs",
    ):
        active = [r for r in RULES if r[2](posed)]
        hits = list(lint_lines(posed, lines, active))
        fired |= {rule for _, _, rule, _ in hits}
        good_hits = list(lint_lines(posed, GOOD_SNIPPET.splitlines(), active))
        if good_hits:
            print(f"self-test: false positives on known-good snippet "
                  f"(posed as {posed.parent.name}/):", file=sys.stderr)
            for _, lineno, rule, _ in good_hits:
                print(f"  line {lineno}: [{rule}]", file=sys.stderr)
            ok = False
    expected = {name for name, _, _, _ in RULES}
    missing = expected - fired
    if missing:
        print(f"self-test: rules never fired on fixture: {sorted(missing)}",
              file=sys.stderr)
        ok = False
    print("self-test: ok" if ok else "self-test: FAILED")
    return 0 if ok else 1


def main(argv) -> int:
    if "--self-test" in argv:
        return self_test()
    return report(lint_tree())


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
